"""Streaming session API (ISSUE 4): op registry, incremental DAG,
buffer futures, concurrent submitters, exception propagation, lifecycle,
and bit-identical equivalence with batch run_graph."""

import threading

import numpy as np
import pytest

from repro.apps.radar import make_runtime, make_session, submit_2fzf
from repro.apps.synthetic import build_fork_join, submit_fork_join
from repro.core import api as rimms
from repro.core.graph import GraphBuilder, build_graph
from repro.core.hete import AllocError, HeteContext, hete_sync
from repro.core.runtime import Task


# ---------------------------------------------------------------------------
# op registry
# ---------------------------------------------------------------------------


def test_op_decorator_registers_per_kind_variants():
    reg = rimms.OpRegistry()

    @rimms.op("scale", kinds=("cpu", "gpu"), registry=reg)
    def scale(ins, *, k=2.0):
        return ins[0] * k

    assert reg.kinds("scale") == ["cpu", "gpu"]
    assert reg.get("scale", "cpu") is scale
    assert reg.ops() == ["scale"]
    # the function stays directly callable
    np.testing.assert_allclose(scale([np.ones(4)], k=3.0), 3.0)


def test_op_double_registration_rejected_unless_replace():
    reg = rimms.OpRegistry()

    @rimms.op("f", kinds=("cpu",), registry=reg)
    def f1(ins):
        return ins[0]

    with pytest.raises(ValueError, match="already registered"):
        @rimms.op("f", kinds=("cpu",), registry=reg)
        def f2(ins):
            return ins[0]

    @rimms.op("f", kinds=("cpu",), registry=reg, replace=True)
    def f3(ins):
        return ins[0]

    assert reg.get("f", "cpu") is f3


def test_registry_install_missing_only_keeps_manual_kernels():
    rt, _ = make_runtime(policy="rimms", accelerators=("gpu0",))
    sentinel = lambda ins: ins[0]
    rt.register_kernel("fft", "cpu", sentinel)
    rimms.default_registry.install(rt, missing_only=True)
    assert rt._kernels[("fft", "cpu")] is sentinel


def test_session_runs_custom_op_on_general_purpose_pes():
    """A custom @op variant is usable through a session without touching
    make_emulated_soc's op lists: install extends general-purpose PE
    kinds' supports."""
    reg = rimms.OpRegistry()

    @rimms.op("triple", kinds=("cpu",), registry=reg)
    def triple(ins):
        return ins[0] * 3

    with rimms.Session.emulated(accelerators=(), n_cpu=1,
                                scheduler="round_robin",
                                registry=reg) as s:
        x = s.malloc((8,), np.float32)
        x.data[:] = 2.0
        y = s.submit("triple", [x])
        np.testing.assert_allclose(y.result(), 6.0)


# ---------------------------------------------------------------------------
# incremental DAG builder
# ---------------------------------------------------------------------------


def _mk(ctx, n=16):
    return ctx.malloc((n,), np.complex64)


def test_graph_builder_matches_batch_build_graph():
    """Incremental add() produces exactly the DAG batch build_graph
    does (same edge set) on a fork-join with fragments."""
    ctx = HeteContext()
    parent = ctx.malloc((32,), np.complex64)
    parent.fragment(16)
    a, l, r, o = (_mk(ctx) for _ in range(4))
    tasks = [
        Task("fft", [a], [l]),
        Task("fft", [a], [r]),
        Task("zip", [l, r], [o]),
        Task("fft", [o], [parent[0]]),
        Task("fft", [o], [parent[1]]),
        Task("fft", [parent], [a]),  # reads both fragments, WAR on t0/t1
    ]
    batch = build_graph(tasks)
    builder = GraphBuilder()
    for t in tasks:
        builder.add(t)
    incremental = builder.graph()
    assert batch.edges() == incremental.edges()
    assert batch.critical_path_len == incremental.critical_path_len


def test_graph_builder_tracks_versions_and_last_writer():
    ctx = HeteContext()
    a, b = _mk(ctx), _mk(ctx)
    builder = GraphBuilder()
    assert builder.version_of(b) == 0
    assert builder.last_writer(b) is None
    builder.add(Task("fft", [a], [b]))
    assert builder.version_of(b) == 1
    assert builder.last_writer(b) == 0
    builder.add(Task("ifft", [a], [b]))  # rewrite bumps the version
    assert builder.version_of(b) == 2
    assert builder.last_writer(b) == 1
    # fragments version their parent root
    parent = ctx.malloc((32,), np.complex64)
    parent.fragment(16)
    builder.add(Task("fft", [a], [parent[1]]))
    assert builder.version_of(parent) == 1
    assert builder.last_writer(parent[0]) == 2


# ---------------------------------------------------------------------------
# session: correctness + equivalence with batch modes (acceptance)
# ---------------------------------------------------------------------------


def test_session_radar_chain_matches_numpy():
    with make_session(accelerators=("gpu0", "gpu1")) as s:
        bufs = submit_2fzf(s, 256, seed=7)
        want = np.fft.ifft(
            np.fft.fft(bufs["a"].data) * np.fft.fft(bufs["b"].data)
        ).astype(np.complex64)
        np.testing.assert_allclose(bufs["out"].result(), want, atol=1e-4)


def test_session_bit_identical_to_run_graph_on_forkjoin():
    """Acceptance: the streaming session path produces bit-identical
    outputs and per-pair copy counts to batch run_graph under the rimms
    policy + static round_robin placement on the radar fork-join."""
    kw = dict(ways=4, n=1024, depth=2, seed=3)
    s = make_session(policy="rimms", scheduler="round_robin",
                     n_cpu=0, accelerators=("gpu0", "gpu1"))
    futs = submit_fork_join(s, **kw)
    out_stream = futs["out"].result().copy()
    s.barrier()
    snap_stream = s.ledger.snapshot()
    s.close()

    rt, ctx = make_runtime(policy="rimms", scheduler="round_robin",
                           n_cpu=0, accelerators=("gpu0", "gpu1"))
    bufs, tasks = build_fork_join(ctx, **kw)
    rt.run_graph(tasks)
    out_batch = hete_sync(bufs["out"], context=ctx).copy()
    snap_batch = ctx.ledger.snapshot()

    assert np.array_equal(out_stream, out_batch)
    assert snap_stream["by_pair"] == snap_batch["by_pair"]
    assert snap_stream["total_copies"] == snap_batch["total_copies"]


def test_session_heft_windowed_placement_correct_and_multi_pe():
    with make_session(scheduler="heft", n_cpu=0,
                      accelerators=("gpu0", "gpu1")) as s:
        futs = submit_fork_join(s, ways=4, n=2048, depth=2, seed=1)
        out = futs["out"].result()
        assert np.all(np.isfinite(out))
        s.barrier()
        rep = s.report()
    assert rep["n_tasks"] == rep["n_completed"]
    used = {pe for _, pe in s.runtime.task_log}
    assert used == {"gpu0", "gpu1"}
    assert rep["makespan_model"] > 0


def test_session_report_replay_is_deterministic():
    """Same submissions → exactly the same replayed modeled makespan,
    run to run (the bench_stream gate depends on this)."""
    makespans = []
    for _ in range(2):
        with make_session(scheduler="round_robin", n_cpu=0,
                          accelerators=("gpu0", "gpu1")) as s:
            submit_fork_join(s, ways=4, n=1024, depth=2, seed=5)
            s.barrier()
            makespans.append(s.report()["makespan_model"])
    assert makespans[0] == makespans[1]


# ---------------------------------------------------------------------------
# session: concurrency + out-of-order completion
# ---------------------------------------------------------------------------


def test_concurrent_submitter_threads():
    """Multi-tenant streaming: N client threads submit radar chains
    against ONE session; every client's output matches numpy."""
    s = make_session(scheduler="round_robin", n_cpu=0,
                     accelerators=("gpu0", "gpu1"))
    results, errors = {}, []

    def client(i):
        try:
            bufs = submit_2fzf(s, 128, seed=i, tag=f"_c{i}",
                               pins=(f"gpu{i % 2}",) * 4)
            got = bufs["out"].result(timeout=60)
            want = np.fft.ifft(
                np.fft.fft(bufs["a"].data) * np.fft.fft(bufs["b"].data)
            ).astype(np.complex64)
            results[i] = (got, want)
        except BaseException as e:  # pragma: no cover - diagnostic
            errors.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(results) == 8
    for got, want in results.values():
        np.testing.assert_allclose(got, want, atol=1e-4)
    s.barrier()
    assert s.report()["n_completed"] == 8 * 4
    s.close()


def test_out_of_order_completion_and_result():
    """A short independent chain completes (and resolves) while a long
    chain is still streaming; waiting on futures in reverse submission
    order works."""
    with make_session(scheduler="round_robin", n_cpu=0,
                      accelerators=("gpu0", "gpu1")) as s:
        long = submit_fork_join(s, ways=8, n=4096, depth=3, seed=2)
        short = submit_2fzf(s, 64, seed=9, tag="_s")
        short_out = short["out"].result(timeout=60)  # before the long chain
        long_out = long["out"].result(timeout=120)
        want = np.fft.ifft(
            np.fft.fft(short["a"].data) * np.fft.fft(short["b"].data)
        ).astype(np.complex64)
        np.testing.assert_allclose(short_out, want, atol=1e-4)
        assert np.all(np.isfinite(long_out))


def test_resubmitted_buffer_result_waits_for_latest_writer():
    """result() synchronizes the buffer: after resubmitting the same
    buffer as an output, it resolves to the newest submitted content."""
    with make_session(accelerators=("gpu0",), n_cpu=0,
                      scheduler="round_robin") as s:
        x = s.malloc((64,), np.complex64)
        x.data[:] = 1.0
        f1 = s.submit("fft", [x])
        f2 = s.submit("ifft", [f1], out=f1)  # overwrite f1's buffer
        np.testing.assert_allclose(f2.result(), x.data, atol=1e-4)
        assert f1.version == 1 and f2.version == 2
        # f1's handle now resolves to the rewritten (latest) bytes too
        np.testing.assert_allclose(f1.result(), x.data, atol=1e-4)


# ---------------------------------------------------------------------------
# session: exception propagation
# ---------------------------------------------------------------------------


def _boom_registry():
    reg = rimms.OpRegistry()

    @rimms.op("good", kinds=("cpu",), registry=reg)
    def good(ins):
        return ins[0] * 2

    @rimms.op("boom", kinds=("cpu",), registry=reg)
    def boom(ins):
        raise RuntimeError("kernel exploded")

    return reg


def test_exception_propagates_through_future_result():
    with rimms.Session.emulated(accelerators=(), n_cpu=1,
                                scheduler="round_robin",
                                registry=_boom_registry()) as s:
        x = s.malloc((8,), np.float32)
        y = s.submit("boom", [x])
        with pytest.raises(RuntimeError, match="kernel exploded"):
            y.result(timeout=30)
        assert isinstance(y.exception(), RuntimeError)
        # observed via result(): the exiting barrier must not re-raise


def test_failure_fails_dependent_subtree_but_not_independent_chains():
    s = rimms.Session.emulated(accelerators=(), n_cpu=1,
                               scheduler="round_robin",
                               registry=_boom_registry())
    x = s.malloc((8,), np.float32)
    x.data[:] = 1.0
    bad = s.submit("boom", [x])
    dependent = s.submit("good", [bad])
    independent = s.submit("good", [x])
    with pytest.raises(RuntimeError, match="kernel exploded"):
        dependent.result(timeout=30)
    np.testing.assert_allclose(independent.result(timeout=30), 2.0)
    # both failures observed through results → barrier is clean
    s.barrier()
    # the stream keeps flowing after a failure
    again = s.submit("good", [independent])
    np.testing.assert_allclose(again.result(timeout=30), 4.0)
    s.close()


def test_deep_dependent_chain_fails_without_recursion_blowup():
    """A failure at the head of a deeper-than-recursion-limit admitted
    chain must cascade iteratively: every dependent fails, the barrier
    raises (once), and the worker thread survives."""
    import sys

    depth = sys.getrecursionlimit() + 200
    s = rimms.Session.emulated(accelerators=(), n_cpu=1,
                               scheduler="round_robin",
                               registry=_boom_registry())
    x = s.malloc((4,), np.float32)
    cur = s.submit("boom", [x])
    for _ in range(depth):
        cur = s.submit("good", [cur])
    with pytest.raises(RuntimeError, match="kernel exploded"):
        cur.result(timeout=60)
    s.barrier()  # cascade observed through the tail future
    rep = s.report()
    assert rep["n_failed"] == depth + 1
    # the stream (and its PE worker) is still alive after the cascade
    ok = s.submit("good", [x])
    assert ok.result(timeout=30) is not None
    s.close()


def test_scalar_output_shape_is_respected():
    """out_shape=() (a 0-d scalar buffer) must not be discarded as
    falsy in favour of the input's shape."""
    reg = rimms.OpRegistry()

    @rimms.op("total", kinds=("cpu",), registry=reg)
    def total(ins):
        return np.float32(ins[0].sum())

    with rimms.Session.emulated(accelerators=(), n_cpu=1,
                                scheduler="round_robin",
                                registry=reg) as s:
        x = s.malloc((8,), np.float32)
        x.data[:] = 2.0
        f = s.submit("total", [x], out_shape=(), out_dtype=np.float32)
        assert f.shape == ()
        np.testing.assert_allclose(f.result(timeout=30), 16.0)


def test_barrier_raises_unobserved_failure_once():
    s = rimms.Session.emulated(accelerators=(), n_cpu=1,
                               scheduler="round_robin",
                               registry=_boom_registry())
    x = s.malloc((8,), np.float32)
    s.submit("boom", [x])
    with pytest.raises(RuntimeError, match="kernel exploded"):
        s.barrier()
    s.barrier()  # observed now: second barrier is clean
    s.close()


def test_bad_pin_fails_future_not_submitter():
    with rimms.Session.emulated(accelerators=("gpu0",),
                                scheduler="round_robin") as s:
        x = s.malloc((8,), np.complex64)
        y = s.submit("fft", [x], pin="no_such_pe")
        with pytest.raises(KeyError):
            y.result(timeout=30)


def test_unknown_op_fails_future():
    with rimms.Session.emulated(accelerators=("gpu0",),
                                scheduler="heft") as s:
        x = s.malloc((8,), np.complex64)
        y = s.submit("no_such_op", [x])
        with pytest.raises(LookupError):
            y.result(timeout=30)


# ---------------------------------------------------------------------------
# session: buffer lifecycle (free-after-last-use)
# ---------------------------------------------------------------------------


def test_free_after_last_use_defers_until_stream_drains():
    with make_session(accelerators=("gpu0",), n_cpu=0,
                      scheduler="round_robin") as s:
        x = s.malloc((1 << 16,), np.complex64)
        x.data[:] = 1.0
        y = s.submit("fft", [x])
        freed_now = x.free()  # may still be read by the in-flight fft
        out = y.result(timeout=60)
        s.barrier()
        assert x.hete.freed  # released after its last reader completed
        assert np.all(np.isfinite(out))
        assert not freed_now or x.hete.freed


def test_free_idle_buffer_is_immediate_and_double_free_raises():
    with make_session(accelerators=("gpu0",)) as s:
        x = s.malloc((64,), np.complex64)
        assert s.free(x) is True
        assert x.hete.freed
        with pytest.raises(AllocError, match="double hete_free"):
            s.free(x)


def test_submit_after_close_raises():
    s = make_session(accelerators=("gpu0",))
    s.close()
    with pytest.raises(RuntimeError, match="closed"):
        s.malloc((8,))
    with pytest.raises(RuntimeError, match="closed"):
        s.submit("fft", [np.zeros(8, np.complex64)])


def test_numpy_inputs_are_adopted():
    with make_session(accelerators=("gpu0",), n_cpu=0,
                      scheduler="round_robin") as s:
        sig = (np.arange(64) % 7).astype(np.complex64)
        f = s.submit("fft", [sig])
        np.testing.assert_allclose(
            f.result(timeout=30), np.fft.fft(sig).astype(np.complex64),
            atol=1e-4)


# ---------------------------------------------------------------------------
# runtime stats hygiene (ISSUE 4 satellite)
# ---------------------------------------------------------------------------


def test_run_resets_task_log_and_rr_state_each_run():
    """Cross-run state leaks fixed: task_log holds exactly the last
    run's placements and round-robin rotation restarts, so identical
    task lists place identically on every run."""
    from repro.apps.radar import build_2fzf

    rt, ctx = make_runtime(policy="rimms", n_cpu=0,
                           accelerators=("gpu0", "gpu1"))
    bufs, tasks = build_2fzf(ctx, 128, seed=1)
    rt.run(tasks)
    first = list(rt.task_log)
    assert len(first) == len(tasks)
    rt.run(tasks)
    assert rt.task_log == first  # same placements, not accumulated
    assert rt._rr_state != {} and len(rt.task_log) == len(tasks)
    rt.run_graph(tasks)
    assert len(rt.task_log) == len(tasks)
    rt.reset_stats()
    assert rt.task_log == [] and rt._rr_state == {}
    assert rt.last_report is None and rt.last_makespan_model == 0.0
