"""Runtime dispatch + memory policies: the paper's copy-count claims."""

import numpy as np

from repro.apps.radar import (
    build_2fft,
    build_2fzf,
    build_3zip,
    build_pd,
    make_runtime,
)
from repro.core.hete import hete_sync


def run_chain(builder, policy, pins_key="pins", **kw):
    rt, ctx = make_runtime(policy=policy, accelerators=("gpu0",))
    bufs, tasks = builder(ctx, **kw)
    rt.run(tasks)
    return rt, ctx, bufs


def test_2fft_copy_elimination_acc_acc():
    """Paper Fig 5: ACC-ACC — reference 4 copies, RIMMS 1 (−3)."""
    _, ctx_ref, _ = run_chain(
        lambda c: build_2fft(c, 256, pins=("gpu0", "gpu0")), "reference")
    _, ctx_rim, _ = run_chain(
        lambda c: build_2fft(c, 256, pins=("gpu0", "gpu0")), "rimms")
    assert ctx_ref.ledger.total_copies == 4
    assert ctx_rim.ledger.total_copies == 1


def test_2fft_copy_elimination_cpu_acc():
    """Paper Fig 5: CPU-ACC — RIMMS saves exactly one copy."""
    _, ctx_ref, _ = run_chain(
        lambda c: build_2fft(c, 256, pins=("cpu0", "gpu0")), "reference")
    _, ctx_rim, _ = run_chain(
        lambda c: build_2fft(c, 256, pins=("cpu0", "gpu0")), "rimms")
    assert ctx_ref.ledger.total_copies - ctx_rim.ledger.total_copies == 1


def test_2fft_results_match_and_correct():
    outs = {}
    for policy in ("reference", "rimms"):
        _, ctx, bufs = run_chain(
            lambda c: build_2fft(c, 128, pins=("gpu0", "gpu0"), seed=3), policy)
        outs[policy] = hete_sync(bufs["out"], context=ctx).copy()
        np.testing.assert_allclose(
            outs[policy], bufs["in"].data, atol=1e-4
        )  # IFFT(FFT(x)) == x
    np.testing.assert_allclose(outs["reference"], outs["rimms"], atol=1e-5)


def test_2fzf_numerics_vs_numpy():
    _, ctx, bufs = run_chain(
        lambda c: build_2fzf(c, 64, pins=("gpu0",) * 4, seed=1), "rimms")
    want = np.fft.ifft(np.fft.fft(bufs["a"].data) * np.fft.fft(bufs["b"].data))
    np.testing.assert_allclose(
        hete_sync(bufs["out"], context=ctx), want.astype(np.complex64),
        atol=1e-4,
    )


def test_3zip_gpu_only_counts():
    """Fig 8 flow: reference bounces every hop (6 in-copies + 3 out),
    RIMMS stages inputs once and keeps intermediates on device."""
    _, ctx_ref, _ = run_chain(
        lambda c: build_3zip(c, 128, pins=("gpu0",) * 3), "reference")
    _, ctx_rim, _ = run_chain(
        lambda c: build_3zip(c, 128, pins=("gpu0",) * 3), "rimms")
    assert ctx_ref.ledger.total_copies == 9
    assert ctx_rim.ledger.total_copies == 4  # four fresh inputs only


def test_round_robin_batches_of_four():
    """Paper §5.4: 3 CPUs + 1 GPU round robin."""
    rt, ctx = make_runtime(policy="rimms", n_cpu=3, accelerators=("gpu0",))
    bufs, tasks = build_pd(ctx, ways=8, n=64)
    rt.run(tasks)
    fft_pes = [pe for name, pe in rt.task_log if name.startswith("fft")]
    assert fft_pes[:4] == ["cpu0", "cpu1", "cpu2", "gpu0"]


def test_data_affinity_scheduler_prefers_data_location():
    rt, ctx = make_runtime(policy="rimms", n_cpu=1,
                           accelerators=("gpu0",), scheduler="data_affinity")
    bufs, tasks = build_2fft(ctx, 128)
    rt.run(tasks)
    # second task should follow the data produced by the first
    assert rt.task_log[0][1] == rt.task_log[1][1]


def test_data_affinity_tie_break_is_deterministic():
    """Satellite (ISSUE 1): equal byte scores resolve by stable PE-name
    ordering, so placement is reproducible across runs and PE list
    orderings."""
    placements = []
    for trial in range(3):
        rt, ctx = make_runtime(policy="rimms", n_cpu=0,
                               accelerators=("gpu1", "gpu0", "gpu2"),
                               scheduler="data_affinity")
        # fresh host inputs: zero bytes valid at every accelerator → tie
        bufs, tasks = build_2fft(ctx, 64)
        rt.run(tasks)
        placements.append([pe for _, pe in rt.task_log])
    assert placements[0] == placements[1] == placements[2]
    # the tie must resolve to the lexicographically-smallest PE name,
    # regardless of the order accelerators were registered in
    assert placements[0][0] == "gpu0"


def test_pd_fragment_allocation_counts():
    """§3.2.3: with fragment(), one arena search per data point."""
    rt, ctx = make_runtime(policy="rimms", accelerators=("gpu0",))
    arena = list(ctx.spaces.values())[-1].arena
    build_pd(ctx, ways=16, n=64, use_fragment=True)
    n_frag = arena.n_allocs
    rt2, ctx2 = make_runtime(policy="rimms", accelerators=("gpu0",))
    build_pd(ctx2, ways=16, n=64, use_fragment=False)
    # fragment path does ≤ 1 alloc per data point (host-side arenas are
    # only engaged when spaces are passed; here we compare host mallocs)
    assert n_frag <= arena.n_allocs
