"""Allocator unit + property tests (bitset & next-fit marking systems).

The property tests use ``hypothesis`` when available; without it they
skip cleanly and a deterministic pseudo-random fallback covers the same
invariants (see ``requirements-dev.txt`` for the full dev toolchain).
"""

import random

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.allocator import (
    AllocError, BitsetAllocator, NextFitAllocator, make_allocator,
)


@pytest.mark.parametrize("kind", ["bitset", "nextfit"])
def test_basic_alloc_free(kind):
    a = make_allocator(kind, 1 << 16, 256)
    e1 = a.alloc(1000)
    e2 = a.alloc(500)
    assert e1.end <= e2.offset or e2.end <= e1.offset
    a.free(e1)
    a.free(e2)
    assert a.used_bytes == 0


@pytest.mark.parametrize("kind", ["bitset", "nextfit"])
def test_double_free_raises(kind):
    a = make_allocator(kind, 1 << 12, 64)
    e = a.alloc(64)
    a.free(e)
    with pytest.raises(AllocError):
        a.free(e)


def test_bitset_block_rounding():
    a = BitsetAllocator(4096, 256)
    e = a.alloc(1)  # rounds to one block
    assert e.size == 256
    assert a.metadata_bytes() == 2  # 16 blocks -> 2 bytes


def test_bitset_exhaustion():
    a = BitsetAllocator(1024, 256)
    a.alloc(1024)
    with pytest.raises(AllocError):
        a.alloc(1)


def test_nextfit_split_and_coalesce():
    a = NextFitAllocator(1000)
    e1, e2, e3 = a.alloc(100), a.alloc(200), a.alloc(300)
    a.free(e2)
    a.free(e1)  # must coalesce with e2's hole
    segs = a.segments()
    assert (0, 300, False) in segs
    a.free(e3)
    assert a.segments() == [(0, 1000, False)]


def test_nextfit_exact_size_split():
    a = NextFitAllocator(1000)
    e = a.alloc(123)
    assert e.size == 123  # paper: first segment sized precisely


def test_nextfit_rolling_cursor_is_fast():
    """Next-fit should not rescan from the start each time (paper: 2.55×
    faster than bitset) — allocation steps stay O(1) amortized."""
    a = NextFitAllocator(1 << 20)
    a.reset_counters()
    for _ in range(1000):
        a.alloc(64)
    assert a.n_steps <= 2 * a.n_allocs


def test_fragmentation_fallback_behaviour():
    a = NextFitAllocator(1000)
    xs = [a.alloc(100) for _ in range(10)]
    for x in xs[::2]:
        a.free(x)
    # 500 bytes free but fragmented into 100-byte holes
    with pytest.raises(AllocError):
        a.alloc(200)
    assert a.free_bytes == 500


def _check_invariants(kind, ops):
    """Invariants under arbitrary alloc/free sequences: live extents
    never overlap, stay in bounds, used_bytes is conserved, and freeing
    everything restores an empty arena."""
    cap = 1 << 14
    a = make_allocator(kind, cap, 64)
    live = []
    for is_alloc, size in ops:
        if is_alloc or not live:
            try:
                e = a.alloc(size)
            except AllocError:
                continue
            assert 0 <= e.offset and e.end <= cap
            for other in live:
                assert e.end <= other.offset or other.end <= e.offset
            live.append(e)
        else:
            a.free(live.pop(len(live) // 2))
    assert a.used_bytes == sum(e.size for e in live)
    for e in live:
        a.free(e)
    assert a.used_bytes == 0
    if kind == "nextfit":
        assert a.segments() == [(0, cap, False)]
    else:
        assert a._bits == 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(
        kind=st.sampled_from(["bitset", "nextfit"]),
        ops=st.lists(
            st.tuples(st.booleans(), st.integers(1, 2000)), min_size=1,
            max_size=120,
        ),
    )
    def test_property_no_overlap_and_conservation(kind, ops):
        _check_invariants(kind, ops)
else:
    def test_property_no_overlap_and_conservation():
        pytest.importorskip("hypothesis")


@pytest.mark.parametrize("kind", ["bitset", "nextfit"])
def test_random_ops_invariants_fallback(kind):
    """Deterministic pseudo-random coverage of the same invariants —
    always runs, so the core assertions hold even without hypothesis."""
    rng = random.Random(0xA110C)
    for _ in range(40):
        ops = [
            (rng.random() < 0.6, rng.randint(1, 2000))
            for _ in range(rng.randint(1, 120))
        ]
        _check_invariants(kind, ops)
