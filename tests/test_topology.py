"""Interconnect topology subsystem (ISSUE 3): routing, contention,
routed staging accounting, spill-to-peer eviction, and the HEFT
insertion-based slot search."""

import numpy as np
import pytest

from repro.core.executor import commit_slot, insert_slot
from repro.core.hete import HeteContext, MemorySpace, hete_sync
from repro.core.locations import HOST, BandwidthModel, Location
from repro.core.topology import (
    Topology, TopologyBandwidthModel, TopologyError, build_preset,
)

G0, G1 = Location("device", "gpu0"), Location("device", "gpu1")


def _np_space(loc, capacity=None):
    return MemorySpace(
        loc, capacity=capacity,
        ingest=lambda a: a.copy(), egress=lambda a: np.asarray(a),
    )


def make_ctx(topology, caps=(4096, 1 << 20)):
    ctx = HeteContext()
    ctx.ledger.bandwidth_model = TopologyBandwidthModel(topology)
    ctx.register_space(_np_space(G0, caps[0]))
    ctx.register_space(_np_space(G1, caps[1]))
    return ctx


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def test_multi_hop_cost_equals_sum_of_hops():
    topo = build_preset("host_bridged_fpga", [G0, G1])
    hops = topo.route(G0, G1)
    assert [l.label for l in hops] == [
        "device:gpu0->host:cpu", "host:cpu->device:gpu1",
    ]
    n = 1 << 20
    assert topo.seconds(G0, G1, n) == pytest.approx(
        sum(l.seconds(n) for l in hops)
    )
    # same-location transfers are free and have no hops
    assert topo.route(G0, G0) == ()
    assert TopologyBandwidthModel(topo).seconds(G0, G0, n) == 0.0


def test_dijkstra_prefers_cheap_direct_link():
    topo = build_preset("nvlink_mesh", [G0, G1])
    assert [l.label for l in topo.route(G0, G1)] == [
        "device:gpu0->device:gpu1",
    ]
    # pcie tree: peer traffic turns around at the switch, not the host
    tree = build_preset("pcie_tree", [G0, G1])
    labels = [l.label for l in tree.route(G0, G1)]
    assert labels == [
        "device:gpu0->bridge:pcie0", "bridge:pcie0->device:gpu1",
    ]


def test_unreachable_location_raises_clear_error():
    topo = build_preset("nvlink_mesh", [G0])
    with pytest.raises(TopologyError, match="no route"):
        topo.route(G0, G1)
    # disconnected node (registered but linkless) also raises
    topo2 = Topology("split")
    topo2.add_link(HOST, G0, bandwidth=1e9)
    topo2.add_node(G1)
    with pytest.raises(TopologyError, match="does not connect"):
        topo2.route(G0, G1)
    with pytest.raises(TopologyError, match="unknown topology preset"):
        build_preset("warp_drive", [G0])


def test_emulated_soc_preset_matches_scalar_model():
    """The flat preset prices exactly like the scalar defaults, so
    swapping it in changes no modeled numbers."""
    topo = TopologyBandwidthModel(build_preset("emulated_soc", [G0, G1]))
    scalar = BandwidthModel()
    for src, dst in [(HOST, G0), (G0, HOST), (G0, G1)]:
        assert topo.seconds(src, dst, 1 << 16) == pytest.approx(
            scalar.seconds(src, dst, 1 << 16)
        )


# ---------------------------------------------------------------------------
# contention
# ---------------------------------------------------------------------------


def test_contention_serializes_transfers_on_shared_bridge_link():
    """Two concurrent host→device transfers to different FPGAs use
    disjoint links (overlap); two to the SAME device share its link and
    serialize."""
    topo = build_preset("host_bridged_fpga", [G0, G1])
    n = 1 << 20
    s0, e0, _ = topo.transfer(HOST, G0, n, at=0.0)
    s1, e1, _ = topo.transfer(HOST, G1, n, at=0.0)
    assert s0 == s1 == 0.0  # disjoint udma links: true overlap
    s2, e2, _ = topo.transfer(HOST, G0, n, at=0.0)
    assert s2 == pytest.approx(e0)  # queued behind the first transfer
    assert e2 == pytest.approx(e0 + topo.seconds(HOST, G0, n))
    # peek (commit=False) reports the wait without reserving
    topo.reset_contention()
    topo.transfer(HOST, G0, n, at=0.0)
    assert topo.queue_delay(HOST, G0, n, at=0.0) == pytest.approx(
        topo.seconds(HOST, G0, n)
    )
    assert topo.queue_delay(HOST, G1, n, at=0.0) == 0.0


def test_device_to_device_on_bridged_platform_occupies_both_host_links():
    topo = build_preset("host_bridged_fpga", [G0, G1])
    n = 1 << 20
    _, _, hops = topo.transfer(G0, G1, n, at=0.0)
    assert [h[0].label for h in hops] == [
        "device:gpu0->host:cpu", "host:cpu->device:gpu1",
    ]
    # store-and-forward: second hop starts when the first delivered
    assert hops[1][1] == pytest.approx(hops[0][2])


# ---------------------------------------------------------------------------
# routed staging accounting
# ---------------------------------------------------------------------------


def test_stage_records_per_hop_ledger_traffic():
    ctx = make_ctx(build_preset("host_bridged_fpga", [G0, G1]),
                   caps=(1 << 20, 1 << 20))
    a = ctx.malloc((1024,), np.uint8)
    a.data[:] = 3
    v = ctx.ensure(a, G0)
    ctx.mark_written(a, G0, np.asarray(v))
    ctx.ensure(a, G1)  # routed device→device: two link crossings
    snap = ctx.ledger.snapshot()
    assert snap["by_pair"]["device:gpu0->host:cpu"] == 1
    assert snap["by_pair"]["host:cpu->device:gpu1"] == 1
    per_link = snap["per_link"]
    assert per_link["device:gpu0->host:cpu"]["bytes"] == 1024
    # modeled seconds equal the route's store-and-forward sum
    bw = ctx.ledger.bandwidth_model
    want = bw.seconds(HOST, G0, 1024) + bw.seconds(G0, G1, 1024)
    assert snap["modeled_seconds"] == pytest.approx(want)


def test_per_link_summary_totals_match_counters():
    ctx = make_ctx(build_preset("nvlink_mesh", [G0, G1]),
                   caps=(1 << 20, 1 << 20))
    a = ctx.malloc((2048,), np.uint8)
    ctx.ensure(a, G0)
    ctx.ensure(a, G1)
    summary = ctx.ledger.per_link_summary()
    assert sum(r["copies"] for r in summary.values()) == (
        ctx.ledger.total_copies
    )
    assert sum(r["modeled_s"] for r in summary.values()) == pytest.approx(
        ctx.ledger.modeled_seconds
    )


# ---------------------------------------------------------------------------
# spill-to-peer eviction
# ---------------------------------------------------------------------------


def test_spill_to_peer_when_link_cheaper_than_host():
    ctx = make_ctx(build_preset("nvlink_mesh", [G0, G1]))
    a = ctx.malloc((4096,), np.uint8)
    a.data[:] = 7
    v = ctx.ensure(a, G0)
    payload = (np.asarray(v) ^ 0xFF).astype(np.uint8)
    ctx.mark_written(a, G0, payload)  # dirty on gpu0
    b = ctx.malloc((4096,), np.uint8)
    ctx.ensure(b, G0)  # evicts a → peer gpu1 (100 GB/s beats 20 GB/s)
    snap = ctx.ledger.snapshot()
    assert snap["spills_to_peer"] == 1
    assert snap["peer_writeback_bytes"] == 4096
    assert snap["by_pair"]["device:gpu0->device:gpu1"] == 1
    assert a.last_location == G1 and G0 not in a.copies
    # the root's extent migrated: gone from gpu0's arena, live in gpu1's
    assert id(a) not in ctx.spaces[G0].arena.tags().values()
    assert id(a) in ctx.spaces[G1].arena.tags().values()
    # host bytes were NOT touched by the spill (still stale)…
    np.testing.assert_array_equal(a.data, 7)
    # …until sync pulls from the peer, bit-identically
    np.testing.assert_array_equal(hete_sync(a, context=ctx), payload)


def test_host_bridged_platform_never_spills_to_peer():
    """When every peer route goes through the host, host write-back is
    always at least as cheap — spill stays host-bound."""
    ctx = make_ctx(build_preset("host_bridged_fpga", [G0, G1]))
    a = ctx.malloc((4096,), np.uint8)
    v = ctx.ensure(a, G0)
    ctx.mark_written(a, G0, np.asarray(v) + 1)
    b = ctx.malloc((4096,), np.uint8)
    ctx.ensure(b, G0)
    snap = ctx.ledger.snapshot()
    assert snap["total_evictions"] == 1
    assert snap["spills_to_peer"] == 0
    assert a.last_location == HOST


def test_spill_to_peer_skipped_when_peer_full():
    """A peer arena without room cannot take the spill (no cascades):
    write-back falls back to host."""
    ctx = make_ctx(build_preset("nvlink_mesh", [G0, G1]),
                   caps=(4096, 4096))
    filler = ctx.malloc((4096,), np.uint8)
    ctx.ensure(filler, G1)  # peer arena now full
    with filler.pinned(G1):
        a = ctx.malloc((4096,), np.uint8)
        v = ctx.ensure(a, G0)
        ctx.mark_written(a, G0, np.asarray(v) + 9)
        b = ctx.malloc((4096,), np.uint8)
        ctx.ensure(b, G0)
        assert ctx.ledger.snapshot()["spills_to_peer"] == 0
        assert a.last_location == HOST


def test_spill_to_peer_preserves_fragment_aliasing_and_sync():
    """Evicting a parent whose fragments were written on gpu0 spills the
    dirty fragments device→device; host views stay aliased and sync is
    bit-identical."""
    ctx = make_ctx(build_preset("nvlink_mesh", [G0, G1]))
    parent = ctx.malloc((1024,), np.float32)  # 4096 B
    parent.data[:] = 1.0
    frags = parent.fragment(256)
    v0 = ctx.ensure(frags[0], G0)
    ctx.mark_written(frags[0], G0, np.asarray(v0) * 5.0)
    v2 = ctx.ensure(frags[2], G0)
    ctx.mark_written(frags[2], G0, np.asarray(v2) * 9.0)

    other = ctx.malloc((1024,), np.float32)
    ctx.ensure(other, G0)  # evicts parent → dirty fragments to gpu1
    snap = ctx.ledger.snapshot()
    assert snap["spills_to_peer"] == 1
    assert snap["peer_writeback_bytes"] == 2 * 256 * 4
    assert frags[0].last_location == G1 and frags[2].last_location == G1
    assert frags[1].last_location == HOST  # clean fragment untouched
    # host parent bytes still stale for the dirty fragments…
    np.testing.assert_allclose(parent.data[:256], 1.0)
    # …and sync through the aliased views restores coherence
    np.testing.assert_allclose(hete_sync(frags[0], context=ctx), 5.0)
    np.testing.assert_allclose(hete_sync(frags[2], context=ctx), 9.0)
    np.testing.assert_allclose(parent.data[:256], 5.0)
    np.testing.assert_allclose(parent.data[512:768], 9.0)
    # fragment views still write through to the parent
    frags[1].data[:] = 3.0
    np.testing.assert_allclose(parent.data[256:512], 3.0)
    # whole-parent sync gathers spilled fragments bit-identically
    out = hete_sync(parent, context=ctx)
    np.testing.assert_allclose(out[:256], 5.0)
    np.testing.assert_allclose(out[256:512], 3.0)


def test_scalar_model_multi_device_never_spills_to_peer():
    """Spill-to-peer is a topology opt-in: under the default scalar
    model (where device↔device happens to be priced cheaply) eviction
    must stay host-bound so pre-topology semantics hold exactly."""
    ctx = HeteContext()  # default scalar BandwidthModel
    ctx.register_space(_np_space(G0, 4096))
    ctx.register_space(_np_space(G1, 1 << 20))
    a = ctx.malloc((4096,), np.uint8)
    v = ctx.ensure(a, G0)
    ctx.mark_written(a, G0, np.asarray(v) + 1)
    b = ctx.malloc((4096,), np.uint8)
    ctx.ensure(b, G0)  # evicts dirty a
    snap = ctx.ledger.snapshot()
    assert snap["spills_to_peer"] == 0
    assert a.last_location == HOST and G1 not in a.copies


def test_whole_parent_spill_moves_bytes_once():
    """A fragmented parent written wholesale on the device (root + all
    fragments flagged there) spills with ONE whole-parent transfer;
    fragments receive zero-copy slices of the peer buffer."""
    ctx = make_ctx(build_preset("nvlink_mesh", [G0, G1]))
    parent = ctx.malloc((4096,), np.uint8)
    parent.fragment(1024)
    v = ctx.ensure(parent, G0)
    ctx.mark_written(parent, G0, np.asarray(v) + 5)  # root + frags at G0
    other = ctx.malloc((4096,), np.uint8)
    ctx.ensure(other, G0)  # evicts parent → peer
    snap = ctx.ledger.snapshot()
    assert snap["spills_to_peer"] == 1
    assert snap["by_pair"]["device:gpu0->device:gpu1"] == 1  # one copy
    assert snap["per_link"]["device:gpu0->device:gpu1"]["bytes"] == 4096
    assert parent.last_location == G1
    for i in range(4):
        frag = parent[i]
        assert frag.last_location == G1
        # zero-copy: the fragment's peer view aliases the parent buffer
        assert np.shares_memory(frag.copies[G1], parent.copies[G1])
    np.testing.assert_array_equal(hete_sync(parent, context=ctx), 5)


def test_scalar_model_single_device_unaffected():
    """Without a topology and with no peer, eviction behaves exactly as
    before (host write-back, scalar one-record accounting)."""
    ctx = HeteContext()
    ctx.register_space(_np_space(G0, 4096))
    a = ctx.malloc((4096,), np.uint8)
    v = ctx.ensure(a, G0)
    ctx.mark_written(a, G0, np.asarray(v) + 1)
    b = ctx.malloc((4096,), np.uint8)
    ctx.ensure(b, G0)
    snap = ctx.ledger.snapshot()
    assert snap["spills_to_peer"] == 0
    assert snap["by_pair"]["device:gpu0->host:cpu"] == 1
    assert a.last_location == HOST


# ---------------------------------------------------------------------------
# runtime integration
# ---------------------------------------------------------------------------


def _topo_runtime(topology, scheduler="round_robin", arena_bytes=64 << 20):
    from repro.apps.radar import register_kernels
    from repro.core.runtime import Runtime, make_emulated_soc

    pes, ctx = make_emulated_soc(
        n_cpu=0, accelerators=("gpu0", "gpu1"), arena_bytes=arena_bytes,
        topology=topology,
    )
    rt = Runtime(pes, ctx, policy="rimms", scheduler=scheduler)
    register_kernels(rt)
    return rt, ctx


def test_make_emulated_soc_wires_topology_model():
    rt, ctx = _topo_runtime("nvlink_mesh")
    assert isinstance(ctx.ledger.bandwidth_model, TopologyBandwidthModel)
    assert ctx.ledger.bandwidth_model.topology.name == "nvlink_mesh"
    rt.close()


def test_topologies_are_bit_identical_and_replay_deterministic():
    """The topology changes modeled cost, never data: serial and graph
    outputs match across platforms, and the graph executor's topology
    replay yields the same modeled makespan on every run."""
    from repro.apps.synthetic import build_fork_join

    outs, makespans = [], {}
    for topo in ("nvlink_mesh", "host_bridged_fpga"):
        for mode in ("serial", "graph"):
            rt, ctx = _topo_runtime(topo)
            bufs, tasks = build_fork_join(ctx, ways=2, n=1024, depth=1,
                                          seed=3)
            (rt.run if mode == "serial" else rt.run_graph)(tasks)
            outs.append(hete_sync(bufs["out"], context=ctx))
            makespans[(topo, mode)] = rt.last_makespan_model
            rt.close()
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
    # bridged platform pays more modeled time on the same schedule
    assert (makespans[("host_bridged_fpga", "graph")]
            > makespans[("nvlink_mesh", "graph")])
    # replay determinism: same build → exactly the same makespan
    rt, ctx = _topo_runtime("nvlink_mesh")
    bufs, tasks = build_fork_join(ctx, ways=2, n=1024, depth=1, seed=3)
    rt.run_graph(tasks)
    m1 = rt.last_makespan_model
    rt.close()
    assert m1 == makespans[("nvlink_mesh", "graph")]


def test_graph_timeline_has_link_transfer_lanes():
    from repro.apps.synthetic import build_fork_join

    rt, ctx = _topo_runtime("pcie_tree")
    _, tasks = build_fork_join(ctx, ways=2, n=1024, depth=1, seed=0)
    rt.run_graph(tasks)
    xfers = rt.timeline.transfers()
    assert xfers, "topology run recorded no transfer lanes"
    links = {x.link for x in xfers}
    assert any("bridge:pcie0" in l for l in links)
    txt = rt.timeline.gantt(40)
    assert "=" in txt and "bridge:pcie0" in txt
    rt.close()


def test_heft_with_topology_runs_and_places_correctly():
    from repro.apps.radar import build_2fzf

    rt, ctx = _topo_runtime("nvlink_mesh", scheduler="heft")
    bufs, tasks = build_2fzf(ctx, 256, seed=4)
    rt.run_graph(tasks)
    want = np.fft.ifft(
        np.fft.fft(bufs["a"].data) * np.fft.fft(bufs["b"].data)
    ).astype(np.complex64)
    np.testing.assert_allclose(
        hete_sync(bufs["out"], context=ctx), want, atol=1e-4)
    rt.close()


# ---------------------------------------------------------------------------
# HEFT insertion-based slot search
# ---------------------------------------------------------------------------


def test_insert_slot_fills_idle_gap():
    busy = []
    commit_slot(busy, 0.0, 1.0)
    commit_slot(busy, 3.0, 1.0)
    # a unit task ready at t=0.5 slides into the [1, 3) gap…
    assert insert_slot(busy, 0.5, 1.0) == 1.0
    # …a 3-unit task does not fit there and appends after the last
    assert insert_slot(busy, 0.5, 3.0) == 4.0
    # earliest inside the gap is honoured
    assert insert_slot(busy, 1.5, 1.0) == 1.5
    # empty timeline: start at earliest
    assert insert_slot([], 2.0, 5.0) == 2.0


def test_insert_slot_commit_keeps_intervals_disjoint():
    busy = []
    for earliest, dur in [(0.0, 2.0), (0.0, 1.0), (0.0, 1.0), (1.0, 0.5)]:
        start = insert_slot(busy, earliest, dur)
        # no overlap with any existing interval
        assert all(start + dur <= s or start >= e for s, e in busy)
        commit_slot(busy, start, dur)
    assert busy == sorted(busy)


# ---------------------------------------------------------------------------
# ISSUE 4 satellites: serial per-link contention + prefetch ordering
# ---------------------------------------------------------------------------


def test_serial_modeled_staging_overlaps_disjoint_routes():
    """Serial run() issues a task's input copies concurrently at the
    task's modeled start: two inputs arriving over disjoint links (host
    uplink vs peer NVLink) overlap — staging costs max(), not sum() —
    matching the graph executor's replay pricing."""
    from repro.core.runtime import Task

    rt, ctx = _topo_runtime("nvlink_mesh")
    n = 1 << 14
    x, y, fy, out = (ctx.malloc((n,), np.complex64) for _ in range(4))
    tasks = [
        Task("fft", [y], [fy], pin="gpu1", name="warm"),  # fy lands on gpu1
        Task("zip", [x, fy], [out], pin="gpu0", name="z"),
    ]
    rt.run(tasks)
    ev = {e.task: e for e in rt.timeline.events()}["z"]
    bw = ctx.ledger.bandwidth_model
    t_host = bw.seconds(HOST, G0, x.nbytes)  # host→gpu0 uplink
    t_peer = bw.seconds(G1, G0, fy.nbytes)   # gpu1→gpu0 NVLink
    comp = rt.cost_model.prior_estimate("zip", "gpu", x.nbytes + fy.nbytes)
    stage_m = (ev.model_end - ev.model_start) - comp
    assert stage_m == pytest.approx(max(t_host, t_peer))
    assert stage_m < t_host + t_peer  # strictly better than store-and-forward
    rt.close()


def test_serial_modeled_staging_serializes_on_shared_link():
    """…but two inputs sharing one link (host-bridged UDMA) queue behind
    each other: per-link contention, not naive overlap."""
    from repro.core.runtime import Task

    rt, ctx = _topo_runtime("host_bridged_fpga")
    n = 1 << 14
    x, y, out = (ctx.malloc((n,), np.complex64) for _ in range(3))
    tasks = [Task("zip", [x, y], [out], pin="gpu0", name="z")]
    rt.run(tasks)
    ev = rt.timeline.events()[0]
    bw = ctx.ledger.bandwidth_model
    t_one = bw.seconds(HOST, G0, x.nbytes)
    comp = rt.cost_model.prior_estimate("zip", "gpu", x.nbytes + y.nbytes)
    stage_m = (ev.model_end - ev.model_start) - comp
    assert stage_m == pytest.approx(2 * t_one)  # serialized on the one link
    # the Gantt transfer lanes on that link must not overlap
    lanes = [t for t in rt.timeline.transfers()
             if t.link == "host:cpu->device:gpu0"]
    assert len(lanes) == 2
    lanes.sort(key=lambda t: t.model_start)
    assert lanes[0].model_end <= lanes[1].model_start + 1e-12
    rt.close()


def test_prefetch_order_issues_least_contended_route_first():
    """Topology-aware prefetch ordering: when a ready batch's input
    routes differ in congestion, the free route's staging is issued
    first; without a topology the submission order is untouched."""
    from repro.core.executor import StreamExecutor
    from repro.core.graph import GraphBuilder
    from repro.core.runtime import Task

    rt, ctx = _topo_runtime("nvlink_mesh")
    ex = StreamExecutor(rt, scheduler="round_robin")
    topo = ctx.ledger.bandwidth_model.topology
    # jam the host→gpu0 uplink with committed traffic
    topo.transfer(HOST, G0, 1 << 24, at=0.0, commit=True)
    n = 1 << 14
    a, b, o1, o2 = (ctx.malloc((n,), np.complex64) for _ in range(4))
    builder = GraphBuilder()
    n0 = builder.add(Task("fft", [a], [o1], name="to_busy_gpu0"))
    n1 = builder.add(Task("fft", [b], [o2], name="to_free_gpu1"))
    ex._nodes.extend([n0, n1])
    assigned = [(0, rt.by_name["gpu0"]), (1, rt.by_name["gpu1"])]
    order = [i for i, _ in ex._prefetch_order(assigned)]
    assert order == [1, 0]  # free route first, congested route last
    # tie (both free) keeps submission order
    topo.reset_contention()
    assert [i for i, _ in ex._prefetch_order(assigned)] == [0, 1]
    ex.close()
    rt.close()
