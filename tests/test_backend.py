"""Process PE-worker backend (ISSUE 7): backend selection, shared-memory
host arenas, thread↔process bit-identity + copy-count parity, worker
failure containment, subprocess lifecycle, platform presets, deprecation
of the batch wrappers, and closed-loop think time in the QoS replay."""

import os
import time
import warnings

import numpy as np
import pytest

import repro.apps.elemwise  # noqa: F401  registers numpy-only test ops
from repro.core import runtime as runtime_mod
from repro.core.api import Session
from repro.core.pworker import ProcessWorker, WorkerDied
from repro.core.qos import ClientState, QoSManager
from repro.core.runtime import (
    BACKENDS, platform_names, register_platform, resolve_backend,
)
from repro.core.shm import SharedHostArena, describe_array, resolve_handle


def _session(backend, **kwargs):
    kwargs.setdefault("policy", "rimms")
    kwargs.setdefault("scheduler", "round_robin")
    kwargs.setdefault("n_cpu", 1)
    kwargs.setdefault("accelerators", ("gpu0",))
    return Session.emulated(backend=backend, **kwargs)


def _close(session):
    session.close()
    session.runtime.close()


def _run_chain(backend):
    """scale→square→csum across cpu0 and gpu0; returns (out, by_pair)."""
    s = _session(backend)
    try:
        a = s.malloc((256,), np.float64)
        b = s.submit("scale", [a], factor=3.0, pin="gpu0")
        c = s.submit("square", [b], pin="cpu0")
        d = s.submit("csum", [c], pin="gpu0")
        out = np.array(d.result(timeout=180))
        return out, s.ledger.snapshot()["by_pair"]
    finally:
        _close(s)


# ---------------------------------------------------------------------------
# backend selection
# ---------------------------------------------------------------------------


def test_resolve_backend_choices():
    assert resolve_backend(None) == "thread"
    assert resolve_backend("thread") == "thread"
    assert resolve_backend("process") == "process"
    assert resolve_backend("auto") in ("thread", "process")


def test_resolve_backend_auto_rule():
    expect = "process" if ((os.cpu_count() or 1) > 1) else None
    resolved = resolve_backend("auto")
    if expect == "process":
        assert resolved == "process"
    else:
        # single CPU: auto is process only if >1 jax device
        import jax

        assert resolved == ("process" if len(jax.devices()) > 1
                            else "thread")


def test_unknown_backend_rejected_with_choices():
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("celery")
    with pytest.raises(ValueError) as ei:
        resolve_backend("celery")
    for choice in BACKENDS:
        assert choice in str(ei.value)


def test_session_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown backend"):
        _session("fork")


def test_session_exposes_backend_and_report():
    s = _session("thread")
    try:
        assert s.backend == "thread"
        assert s.report()["backend"] == "thread"
    finally:
        _close(s)


# ---------------------------------------------------------------------------
# shared-memory host arena
# ---------------------------------------------------------------------------


def test_arena_roundtrip_and_describe():
    arena = SharedHostArena(1 << 16)
    try:
        arr = arena.zeros((32,), np.float64)
        assert arr is not None and not arr.any()
        arr[:] = np.arange(32)
        h = describe_array(arr)
        assert h is not None and h[0] == arena.name
        view = resolve_handle(h)
        assert np.array_equal(view, arr)
        assert not view.flags.writeable
        heap = np.arange(8.0)  # not arena-backed → no handle
        assert describe_array(heap) is None
    finally:
        arena.destroy()


def test_arena_gc_returns_extents():
    arena = SharedHostArena(1 << 16)
    try:
        arr = arena.empty((1024,), np.float64)  # 8 KiB
        assert arr is not None
        used = arena.used_bytes()
        assert used >= 8192
        del arr
        assert arena.used_bytes() < used
    finally:
        arena.destroy()


def test_arena_full_falls_back_to_none():
    arena = SharedHostArena(1 << 12)  # 4 KiB
    try:
        assert arena.zeros((1 << 20,), np.float64) is None
        assert arena.copy_in(np.zeros(1 << 20)) is None
        assert arena.zeros((16,), np.float64) is not None
    finally:
        arena.destroy()
        arena.destroy()  # idempotent


# ---------------------------------------------------------------------------
# thread ↔ process parity (runs on any core count; 1-core is just slow)
# ---------------------------------------------------------------------------


def test_process_backend_bit_identical_to_thread():
    out_t, pairs_t = _run_chain("thread")
    out_p, pairs_p = _run_chain("process")
    assert np.array_equal(out_t, out_p)
    assert pairs_t == pairs_p


def test_process_backend_worker_lifecycle():
    s = _session("process")
    a = s.malloc((64,), np.float64)
    out = s.submit("scale", [a], factor=2.0, pin="gpu0").result(timeout=180)
    assert np.array_equal(np.asarray(out), np.zeros(64))
    pool = s.runtime._process_pool
    assert pool is not None
    pids = pool.pids()
    assert "gpu0" in pids
    procs = pool.procs()
    assert all(p.is_alive() for p in procs)
    _close(s)
    deadline = time.monotonic() + 10
    while any(p.is_alive() for p in procs) and time.monotonic() < deadline:
        time.sleep(0.05)
    assert not any(p.is_alive() for p in procs), "close() must reap workers"


def test_process_backend_kernel_error_propagates():
    s = _session("process")
    try:
        a = s.malloc((8,), np.float64)
        with pytest.raises(RuntimeError, match="boom kernel always fails"):
            s.submit("boom", [a], pin="gpu0").result(timeout=180)
    finally:
        _close(s)


def test_process_backend_worker_death_is_clean_error():
    s = _session("process")
    try:
        a = s.malloc((8,), np.float64)
        with pytest.raises(WorkerDied, match="exit code 17"):
            s.submit("die", [a], pin="gpu0").result(timeout=180)
        # the pool replaces the dead worker: later tasks still run
        out = s.submit("scale", [a], factor=1.0, pin="gpu0").result(
            timeout=180)
        assert np.array_equal(np.asarray(out), np.zeros(8))
    finally:
        _close(s)


def test_unpicklable_kernel_clear_error():
    w = ProcessWorker("t0")
    try:
        with pytest.raises(RuntimeError, match="module-level kernel"):
            w.ensure_kernel(("nope", "cpu"), lambda ins: ins[0])
    finally:
        w.shutdown()


@pytest.mark.skipif((os.cpu_count() or 1) < 2,
                    reason="overlap needs >1 core")
def test_process_backend_overlaps_sleep_kernels():
    s = _session("process", n_cpu=1, accelerators=("gpu0", "gpu1"))
    try:
        bufs = [s.malloc((8,), np.float64) for _ in range(2)]
        for pe, b in zip(("gpu0", "gpu1"), bufs):  # warm both workers
            s.submit("scale", [b], factor=1.0, pin=pe).result(timeout=180)
        t0 = time.perf_counter()
        futs = [s.submit("snooze", [b], seconds=0.4, pin=pe)
                for pe, b in zip(("gpu0", "gpu1"), bufs)]
        for f in futs:
            f.result(timeout=180)
        wall = time.perf_counter() - t0
        assert wall < 0.72, f"no overlap: two 0.4s sleeps took {wall:.2f}s"
    finally:
        _close(s)


def test_process_backend_traced_run_lints_clean():
    from repro.core.trace import trace, trace_lint

    s = _session("process")
    try:
        with trace(s.context) as tc:
            a = s.malloc((64,), np.float64)
            out = s.submit("scale", [a], factor=2.0, pin="gpu0").result(
                timeout=180)
            assert np.asarray(out).shape == (64,)
            s.barrier()
        doc = tc.export()
        assert trace_lint(doc) == []
        worker_spans = [
            e for e in doc["traceEvents"]
            if e.get("ph") == "X"
            and (e.get("args") or {}).get("backend") == "process"
        ]
        assert worker_spans, "no forwarded worker spans in trace"
    finally:
        _close(s)


# ---------------------------------------------------------------------------
# platform presets
# ---------------------------------------------------------------------------


def test_builtin_platforms_registered():
    names = platform_names()
    for preset in ("emulated_soc", "pcie_tree", "nvlink_mesh",
                   "host_bridged_fpga"):
        assert preset in names


def test_session_emulated_platform_shorthand():
    s = Session.emulated("pcie_tree", policy="rimms",
                         accelerators=("gpu0", "gpu1"))
    try:
        assert s.backend == "thread"
    finally:
        _close(s)


def test_unknown_platform_lists_presets():
    with pytest.raises(ValueError, match="unknown platform"):
        Session.emulated("my_quantum_soc")


def test_register_platform_custom_and_duplicate():
    name = "test_soc_pr7"
    register_platform(name, arena_bytes=1 << 20, replace=True)
    assert name in platform_names()
    with pytest.raises(ValueError):
        register_platform(name)
    register_platform(name, arena_bytes=2 << 20, replace=True)


# ---------------------------------------------------------------------------
# deprecation of the batch wrappers
# ---------------------------------------------------------------------------


def test_run_wrappers_warn_once(monkeypatch):
    from repro.apps.radar import make_runtime
    from repro.core.runtime import Task

    monkeypatch.setattr(runtime_mod, "_deprecation_warned", False)
    rt, ctx = make_runtime(policy="rimms", n_cpu=1, accelerators=())
    a = ctx.malloc((16,), np.complex64)
    b = ctx.malloc((16,), np.complex64)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rt.run([Task("fft", [a], [b])])
        rt.run([Task("fft", [a], [b])])
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)
           and "Session" in str(w.message)]
    assert len(dep) == 1, "run() must warn exactly once per process"
    rt.close()


def test_internal_impls_do_not_warn(monkeypatch):
    from repro.apps.radar import make_runtime
    from repro.core.runtime import Task

    monkeypatch.setattr(runtime_mod, "_deprecation_warned", False)
    rt, ctx = make_runtime(policy="rimms", n_cpu=1, accelerators=())
    a = ctx.malloc((16,), np.complex64)
    b = ctx.malloc((16,), np.complex64)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rt._run_impl([Task("fft", [a], [b])])
        rt._run_graph_impl([Task("fft", [a], [b])])
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]
    rt.close()


# ---------------------------------------------------------------------------
# closed-loop think time (QoS replay)
# ---------------------------------------------------------------------------


def test_client_state_think_time_validation():
    assert ClientState("c").think_s == 0.0
    assert ClientState("c", think_s=0.25).think_s == 0.25
    with pytest.raises(ValueError):
        ClientState("c", think_s=-1.0)


def test_qos_client_think_time_param():
    qos = QoSManager()
    qos.client("a", think_s=0.5)
    assert qos.params()["clients"]["a"]["think_s"] == 0.5
    qos.client("a", think_s=0.0)
    assert qos.params()["clients"]["a"]["think_s"] == 0.0
    with pytest.raises(ValueError):
        qos.client("b", think_s=-0.1)


def test_session_think_time_stretches_replay():
    """With closed-loop think time a client re-submits only after its
    think delay, so the QoS-replayed makespan grows by ~chains*think_s
    (``report()`` stays QoS-blind; ``qos_report()`` re-enacts
    admission)."""
    def run(think_s):
        s = _session("thread", n_cpu=0, accelerators=("gpu0",))
        try:
            cl = s.client("c0", window=1, think_s=think_s)
            for k in range(4):
                a = s.malloc((64,), np.float64)
                cl.submit("scale", [a], factor=2.0, pin="gpu0",
                          name=f"t{k}").result(timeout=180)
            s.barrier()
            return s.qos_report()["makespan_model"]
        finally:
            _close(s)

    base = run(0.0)
    slow = run(0.01)
    assert slow >= base + 0.025, (
        f"think_s=10ms over 4 sequential tasks should stretch the "
        f"QoS-replayed makespan by >=25ms (got {base:.6f} -> {slow:.6f})"
    )
