"""Task-graph construction + async executor: dependency correctness,
serial-equivalence (bitwise outputs, identical copy counts), HEFT-lite
placement, and modeled-makespan wins on fork-join DAGs."""

import numpy as np
import pytest

from repro.apps.radar import build_2fzf, build_3zip, build_pd, make_runtime
from repro.apps.synthetic import build_diamonds, build_fork_join
from repro.core.graph import CostModel, build_graph
from repro.core.hete import HeteContext, hete_sync
from repro.core.runtime import Task


# ---------------------------------------------------------------------------
# DAG construction
# ---------------------------------------------------------------------------


def _mk(ctx, n=16):
    return ctx.malloc((n,), np.complex64)


def test_raw_edges_linear_chain():
    ctx = HeteContext()
    a, b, c = _mk(ctx), _mk(ctx), _mk(ctx)
    g = build_graph([
        Task("fft", [a], [b], name="t0"),
        Task("ifft", [b], [c], name="t1"),
    ])
    assert g.edges() == [(0, 1)]
    assert g.critical_path_len == 2


def test_raw_fork_and_join_edges():
    ctx = HeteContext()
    a, l, r, o = (_mk(ctx) for _ in range(4))
    g = build_graph([
        Task("fft", [a], [l]),
        Task("fft", [a], [r]),
        Task("zip", [l, r], [o]),
    ])
    assert g.edges() == [(0, 2), (1, 2)]
    assert g.critical_path_len == 2
    assert len(g.roots()) == 2


def test_war_edge_on_overwrite():
    ctx = HeteContext()
    a, b, x = _mk(ctx), _mk(ctx), _mk(ctx)
    g = build_graph([
        Task("zip", [a, b], [x], name="reader"),
        Task("fft", [a], [a], name="overwriter"),  # in-place: WAR on reader
    ])
    assert (0, 1) in g.edges()


def test_waw_edge_between_writers():
    ctx = HeteContext()
    a, x = _mk(ctx), _mk(ctx)
    g = build_graph([
        Task("fft", [a], [x]),
        Task("ifft", [a], [x]),  # rewrites x: WAW
    ])
    assert (0, 1) in g.edges()


def test_fragments_alias_parent_but_not_siblings():
    ctx = HeteContext()
    parent = ctx.malloc((32,), np.complex64)
    parent.fragment(16)
    other = _mk(ctx, 16)
    tasks = [
        Task("fft", [other], [parent[0]], name="w_frag0"),
        Task("fft", [other], [parent[1]], name="w_frag1"),
        Task("fft", [parent], [other], name="r_parent"),  # reads whole parent
    ]
    g = build_graph(tasks)
    edges = g.edges()
    assert (0, 2) in edges and (1, 2) in edges  # parent read sees both writes
    assert (0, 1) not in edges  # sibling fragments are independent


def test_parent_write_orders_before_fragment_read():
    ctx = HeteContext()
    parent = ctx.malloc((32,), np.complex64)
    parent.fragment(16)
    other = _mk(ctx, 32)
    o2 = _mk(ctx, 16)
    g = build_graph([
        Task("fft", [other], [parent], name="w_parent"),
        Task("fft", [parent[1]], [o2], name="r_frag1"),
    ])
    assert (0, 1) in g.edges()


def test_independent_tasks_have_no_edges():
    ctx = HeteContext()
    bufs = [_mk(ctx) for _ in range(4)]
    g = build_graph([
        Task("fft", [bufs[0]], [bufs[1]]),
        Task("fft", [bufs[2]], [bufs[3]]),
    ])
    assert g.n_edges == 0
    assert g.critical_path_len == 1


# ---------------------------------------------------------------------------
# Executor: serial equivalence (acceptance criterion)
# ---------------------------------------------------------------------------


def _run_both(build, *, policy, scheduler="round_robin", graph_kw=None,
              accelerators=("gpu0",), n_cpu=1):
    """Build the same workload twice; run serial and graph; return
    (out_serial, out_graph, snap_serial, snap_graph, rt_s, rt_g)."""
    rt_s, ctx_s = make_runtime(policy=policy, scheduler=scheduler,
                               n_cpu=n_cpu, accelerators=accelerators)
    bufs_s, tasks_s = build(ctx_s)
    rt_g, ctx_g = make_runtime(policy=policy, scheduler=scheduler,
                               n_cpu=n_cpu, accelerators=accelerators)
    bufs_g, tasks_g = build(ctx_g)
    rt_s.run(tasks_s)
    rt_g.run_graph(tasks_g, **(graph_kw or {}))
    out_s = hete_sync(bufs_s["out"], context=ctx_s).copy()
    out_g = hete_sync(bufs_g["out"], context=ctx_g).copy()
    return (out_s, out_g, ctx_s.ledger.snapshot(), ctx_g.ledger.snapshot(),
            rt_s, rt_g)


def test_run_graph_matches_serial_radar_rimms():
    """2FZF radar chain: bitwise-identical outputs + identical per-pair
    copy counts under rimms/round_robin."""
    out_s, out_g, snap_s, snap_g, *_ = _run_both(
        lambda c: build_2fzf(c, 256, seed=7), policy="rimms")
    assert np.array_equal(out_s, out_g)
    assert snap_s["by_pair"] == snap_g["by_pair"]
    assert snap_s["total_copies"] == snap_g["total_copies"]


def test_run_graph_matches_serial_forkjoin_rimms():
    """Synthetic fork-join DAG: bitwise outputs + identical copy counts."""
    out_s, out_g, snap_s, snap_g, *_ = _run_both(
        lambda c: build_fork_join(c, ways=4, n=1024, depth=2, seed=3),
        policy="rimms", n_cpu=0, accelerators=("gpu0", "gpu1"))
    assert np.array_equal(out_s, out_g)
    assert snap_s["by_pair"] == snap_g["by_pair"]


def test_run_graph_matches_serial_3zip():
    """3-stage ZIP pipeline (Fig 4c/8) ported to graph mode: the two leaf
    zips parallelize, the join zip orders after both; results and copy
    counts match serial."""
    out_s, out_g, snap_s, snap_g, rt_s, rt_g = _run_both(
        lambda c: build_3zip(c, 256, seed=11), policy="rimms",
        n_cpu=0, accelerators=("gpu0", "gpu1"))
    assert np.array_equal(out_s, out_g)
    assert snap_s["by_pair"] == snap_g["by_pair"]
    assert rt_g.last_report["critical_path"] == 2  # zip0/zip1 ∥ then zip2


def test_run_graph_matches_serial_reference_policy():
    out_s, out_g, snap_s, snap_g, *_ = _run_both(
        lambda c: build_2fzf(c, 128, seed=5), policy="reference")
    assert np.array_equal(out_s, out_g)
    assert snap_s["by_pair"] == snap_g["by_pair"]


def test_run_graph_fragmented_pd():
    """Pulse-Doppler with fragment() (§3.2.3) runs correctly in graph
    mode: every way's IFFT(FFT(a)*FFT(b)) matches numpy."""
    rt, ctx = make_runtime(policy="rimms", n_cpu=0,
                           accelerators=("gpu0", "gpu1"))
    points, tasks = build_pd(ctx, ways=4, n=64, use_fragment=True)
    rt.run_graph(tasks)
    for i in range(4):
        a = points["a"][1][i].data.copy()
        b = points["b"][1][i].data.copy()
        want = np.fft.ifft(np.fft.fft(a) * np.fft.fft(b)).astype(np.complex64)
        got = hete_sync(points["out"][1][i], context=ctx)
        np.testing.assert_allclose(got, want, atol=1e-4)


def test_run_graph_without_prefetch():
    out_s, out_g, snap_s, snap_g, *_ = _run_both(
        lambda c: build_2fzf(c, 128, seed=2), policy="rimms",
        graph_kw={"prefetch": False})
    assert np.array_equal(out_s, out_g)
    assert snap_s["by_pair"] == snap_g["by_pair"]


def test_run_graph_empty_task_list():
    rt, ctx = make_runtime(policy="rimms")
    assert rt.run_graph([]) == 0.0


def test_run_graph_propagates_kernel_errors():
    rt, ctx = make_runtime(policy="rimms", accelerators=("gpu0",))
    def boom(ins):
        raise RuntimeError("kernel exploded")
    rt.register_kernel("fft", "gpu", boom)
    rt.register_kernel("fft", "cpu", boom)
    a, b = ctx.malloc((8,), np.complex64), ctx.malloc((8,), np.complex64)
    with pytest.raises(RuntimeError, match="kernel exploded"):
        rt.run_graph([Task("fft", [a], [b])])


def test_run_graph_raises_on_bad_pin_of_dependent_task():
    """Regression: a scheduling error for a *non-root* task (raised while
    completing its dependency) must propagate, not hang the run."""
    rt, ctx = make_runtime(policy="rimms", scheduler="heft",
                           accelerators=("gpu0",))
    a, b, c = (_mk(ctx, 32) for _ in range(3))
    tasks = [
        Task("fft", [a], [b], name="ok"),
        Task("ifft", [b], [c], pin="no_such_pe", name="bad_pin"),
    ]
    with pytest.raises(KeyError):
        rt.run_graph(tasks)


def test_run_graph_halts_after_failure():
    """After a task fails, tasks already queued behind it on the same PE
    must not execute (and the error must reach the caller)."""
    rt, ctx = make_runtime(policy="rimms", n_cpu=0, accelerators=("gpu0",))
    def boom(ins):
        raise RuntimeError("boom")
    rt.register_kernel("fft", "gpu", boom)
    bufs = [_mk(ctx, 32) for _ in range(6)]
    tasks = [Task("fft", [bufs[0]], [bufs[1]], pin="gpu0", name="dies")] + [
        Task("zip", [bufs[i], bufs[i]], [bufs[i + 1]], pin="gpu0",
             name=f"queued{i}")
        for i in range(2, 5)
    ]
    with pytest.raises(RuntimeError, match="boom"):
        rt.run_graph(tasks)
    assert rt.task_log == []  # nothing committed after the failure


# ---------------------------------------------------------------------------
# Scheduling: HEFT-lite + makespan
# ---------------------------------------------------------------------------


def test_heft_serial_and_graph_produce_correct_results():
    for mode in ("serial", "graph"):
        rt, ctx = make_runtime(policy="rimms", scheduler="heft",
                               n_cpu=1, accelerators=("gpu0", "gpu1"))
        bufs, tasks = build_2fzf(ctx, 128, seed=9)
        (rt.run if mode == "serial" else rt.run_graph)(tasks)
        want = np.fft.ifft(
            np.fft.fft(bufs["a"].data) * np.fft.fft(bufs["b"].data)
        ).astype(np.complex64)
        np.testing.assert_allclose(
            hete_sync(bufs["out"], context=ctx), want, atol=1e-4)


def test_heft_graph_uses_multiple_pes_on_wide_dag():
    rt, ctx = make_runtime(policy="rimms", scheduler="heft",
                           n_cpu=0, accelerators=("gpu0", "gpu1"))
    _, tasks = build_diamonds(ctx, count=8, n=1024)
    rt.run_graph(tasks)
    used = {pe for _, pe in rt.task_log}
    assert used == {"gpu0", "gpu1"}


def test_graph_modeled_makespan_beats_serial_on_forkjoin():
    """Acceptance: lower modeled makespan than serial dispatch on a
    ≥2-accelerator fork-join workload."""
    def build(ctx):
        return build_fork_join(ctx, ways=4, n=4096, depth=2, seed=1)
    rt_s, ctx_s = make_runtime(policy="rimms", n_cpu=0,
                               accelerators=("gpu0", "gpu1"))
    bufs, tasks = build(ctx_s)
    rt_s.run(tasks)
    rt_g, ctx_g = make_runtime(policy="rimms", n_cpu=0,
                               accelerators=("gpu0", "gpu1"))
    bufs_g, tasks_g = build(ctx_g)
    rt_g.run_graph(tasks_g)
    assert rt_g.last_makespan_model < rt_s.last_makespan_model
    # and the executor's report carries the schedule evidence
    rep = rt_g.last_report
    assert rep["n_tasks"] == len(tasks_g)
    assert rep["critical_path"] < rep["n_tasks"]
    assert len(rep["timeline"]) == len(tasks_g)


def test_timeline_gantt_renders():
    rt, ctx = make_runtime(policy="rimms", n_cpu=0,
                           accelerators=("gpu0", "gpu1"))
    _, tasks = build_fork_join(ctx, ways=2, n=512, depth=1)
    rt.run_graph(tasks)
    txt = rt.timeline.gantt(40)
    assert "gpu0" in txt and "gpu1" in txt and "#" in txt


def test_cost_model_learns_from_observations():
    cm = CostModel()
    prior = cm.estimate("fft", "acc", 1 << 20)
    cm.observe("fft", "acc", 1 << 20, 0.5)  # much slower than prior
    assert cm.estimate("fft", "acc", 1 << 20) > prior
    assert cm.prior_estimate("fft", "acc", 1 << 20) == pytest.approx(prior)


def test_upward_ranks_decrease_along_chain():
    ctx = HeteContext()
    a, b, c, d = (_mk(ctx) for _ in range(4))
    g = build_graph([
        Task("fft", [a], [b]),
        Task("fft", [b], [c]),
        Task("fft", [c], [d]),
    ])
    g.compute_ranks(lambda t: 1.0, lambda t: 0.1)
    ranks = [n.rank for n in g.nodes]
    assert ranks[0] > ranks[1] > ranks[2]
