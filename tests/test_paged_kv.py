"""Paged KV pool: RIMMS allocators managing serving memory."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.allocator import AllocError
from repro.core.paged_kv import (
    SCRATCH_SEQ,
    PagedKVPool,
    gather_kv,
    init_pool_arrays,
    write_token,
)
from repro.core.qos import QuotaExceeded


def test_alloc_extend_free_cycle():
    pool = PagedKVPool(num_pages=32, page_size=8)
    t = pool.alloc_sequence(0, 20)  # 3 pages
    assert len(t) == 3 and pool.free_pages == 29
    t2 = pool.extend_sequence(0, 20)  # 40 tokens → 5 pages
    assert len(t2) == 5
    pool.free_sequence(0)
    assert pool.free_pages == 32


def test_fragment_fast_path_vs_fallback():
    pool = PagedKVPool(num_pages=6, page_size=4, allocator="nextfit")
    a = pool.alloc_sequence(0, 8)   # pages 0-1
    b = pool.alloc_sequence(1, 8)   # pages 2-3
    c = pool.alloc_sequence(2, 8)   # pages 4-5
    pool.free_sequence(0)
    pool.free_sequence(2)
    assert pool.fragment_allocs == 3
    # 4 free pages but split 2+2 — no contiguous run of 3 exists
    d = pool.alloc_sequence(3, 12)
    assert pool.fallback_allocs == 1
    assert len(d) == 3


def test_pool_exhaustion_rolls_back():
    pool = PagedKVPool(num_pages=4, page_size=4)
    pool.alloc_sequence(0, 8)
    with pytest.raises(AllocError):
        pool.alloc_sequence(1, 16)
    # partial grabs must have been rolled back
    assert pool.free_pages == 2


def test_scratch_page_is_pinned_and_unbilled():
    pool = PagedKVPool(num_pages=8, page_size=4, scratch=True)
    assert pool.scratch_page is not None
    assert pool.used_pages == 1  # scratch page is accounted for…
    pool.set_quota("t", 2)
    pool.alloc_sequence(0, 8, tenant="t")  # 2 pages — exactly at quota
    assert pool.tenant_pages("t") == 2  # …but billed to no tenant
    with pytest.raises(ValueError, match="pinned"):
        pool.free_sequence(SCRATCH_SEQ)
    assert pool.used_pages == 3


def test_double_free_raises():
    pool = PagedKVPool(num_pages=8, page_size=4)
    pool.alloc_sequence(0, 4)
    pool.free_sequence(0)
    with pytest.raises(KeyError, match="double free"):
        pool.free_sequence(0)
    assert pool.free_pages == 8


def test_tenant_quota_enforced_and_released():
    pool = PagedKVPool(num_pages=16, page_size=4, scratch=True)
    pool.set_quota("small", 3)
    pool.alloc_sequence(0, 8, tenant="small")  # 2 pages
    with pytest.raises(QuotaExceeded) as ei:
        pool.alloc_sequence(1, 8, tenant="small")  # would be 4 > 3
    assert ei.value.tenant == "small"
    # another tenant is unaffected by the breach
    pool.alloc_sequence(2, 8, tenant="big")
    # freeing returns the pages to the tenant's budget
    pool.free_sequence(0)
    assert pool.tenant_pages("small") == 0
    pool.alloc_sequence(3, 12, tenant="small")  # 3 pages — fits again
    assert pool.tenant_pages("small") == 3


def test_quota_rolls_back_when_pool_exhausted():
    pool = PagedKVPool(num_pages=4, page_size=4)
    pool.set_quota("t", 100)  # quota permits, the shared pool does not
    pool.alloc_sequence(0, 12, tenant="t")  # 3 of 4 pages
    with pytest.raises(AllocError):
        pool.alloc_sequence(1, 8, tenant="t")
    assert pool.tenant_pages("t") == 3  # failed grab not billed
    pool.free_sequence(0)
    assert pool.free_pages == 4


def test_free_realloc_churn_never_double_assigns():
    """Continuous-batching churn: interleaved alloc/free across both
    allocators must keep live page sets disjoint and leak nothing."""
    for allocator in ("bitset", "nextfit"):
        pool = PagedKVPool(num_pages=24, page_size=4, allocator=allocator,
                           scratch=True)
        rng = np.random.default_rng(3)
        live = {}  # seq_id -> set of page ids
        next_id = 0
        for _ in range(200):
            if live and (len(live) > 4 or rng.random() < 0.4):
                sid = sorted(live)[int(rng.integers(len(live)))]
                pool.free_sequence(sid)
                del live[sid]
            else:
                table = pool.alloc_sequence(
                    next_id, int(rng.integers(1, 17)))
                live[next_id] = set(int(p) for p in table)
                next_id += 1
            pages = [p for s in live.values() for p in s]
            assert len(pages) == len(set(pages)), "page double-assigned"
            assert pool.scratch_page not in pages
            assert pool.used_pages == len(pages) + 1
        for sid in sorted(live):
            pool.free_sequence(sid)
        assert pool.used_pages == 1  # only the scratch page remains


def test_write_and_gather_roundtrip():
    pool = PagedKVPool(num_pages=16, page_size=4)
    table = pool.alloc_sequence(7, 16)
    bt = np.zeros((1, 4), np.int32)
    bt[0, : len(table)] = table
    k, _ = init_pool_arrays(16, 4, 2, 8, jnp.float32)
    vals = []
    for pos in range(10):
        new = jnp.full((1, 2, 8), float(pos + 1))
        k = write_token(k, jnp.asarray(bt), jnp.asarray([pos]), new)
        vals.append(pos + 1.0)
    dense = gather_kv(k, jnp.asarray(bt), 16)
    got = np.asarray(dense[0, :10, 0, 0])
    np.testing.assert_allclose(got, vals)
