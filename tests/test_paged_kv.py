"""Paged KV pool: RIMMS allocators managing serving memory."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.allocator import AllocError
from repro.core.paged_kv import PagedKVPool, gather_kv, init_pool_arrays, write_token


def test_alloc_extend_free_cycle():
    pool = PagedKVPool(num_pages=32, page_size=8)
    t = pool.alloc_sequence(0, 20)  # 3 pages
    assert len(t) == 3 and pool.free_pages == 29
    t2 = pool.extend_sequence(0, 20)  # 40 tokens → 5 pages
    assert len(t2) == 5
    pool.free_sequence(0)
    assert pool.free_pages == 32


def test_fragment_fast_path_vs_fallback():
    pool = PagedKVPool(num_pages=6, page_size=4, allocator="nextfit")
    a = pool.alloc_sequence(0, 8)   # pages 0-1
    b = pool.alloc_sequence(1, 8)   # pages 2-3
    c = pool.alloc_sequence(2, 8)   # pages 4-5
    pool.free_sequence(0)
    pool.free_sequence(2)
    assert pool.fragment_allocs == 3
    # 4 free pages but split 2+2 — no contiguous run of 3 exists
    d = pool.alloc_sequence(3, 12)
    assert pool.fallback_allocs == 1
    assert len(d) == 3


def test_pool_exhaustion_rolls_back():
    pool = PagedKVPool(num_pages=4, page_size=4)
    pool.alloc_sequence(0, 8)
    with pytest.raises(AllocError):
        pool.alloc_sequence(1, 16)
    # partial grabs must have been rolled back
    assert pool.free_pages == 2


def test_write_and_gather_roundtrip():
    pool = PagedKVPool(num_pages=16, page_size=4)
    table = pool.alloc_sequence(7, 16)
    bt = np.zeros((1, 4), np.int32)
    bt[0, : len(table)] = table
    k, _ = init_pool_arrays(16, 4, 2, 8, jnp.float32)
    vals = []
    for pos in range(10):
        new = jnp.full((1, 2, 8), float(pos + 1))
        k = write_token(k, jnp.asarray(bt), jnp.asarray([pos]), new)
        vals.append(pos + 1.0)
    dense = gather_kv(k, jnp.asarray(bt), 16)
    got = np.asarray(dense[0, :10, 0, 0])
    np.testing.assert_allclose(got, vals)
