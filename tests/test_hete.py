"""hete_Data / hete_Malloc / hete_Free / hete_Sync semantics (§3.2)."""

import numpy as np
import pytest

from repro.core.allocator import AllocError
from repro.core.hete import HeteContext, MemorySpace, hete_sync
from repro.core.locations import HOST, Location

ACC = Location("device", "acc0")


def make_ctx(tracking="flag"):
    ctx = HeteContext(tracking=tracking)
    ctx.register_space(MemorySpace(
        ACC, capacity=1 << 20, allocator="nextfit",
        ingest=lambda a: a.copy(), egress=lambda a: np.asarray(a),
    ))
    return ctx


def test_malloc_gives_host_buffer():
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.float32)
    assert hd.data.shape == (16,)
    assert hd.last_location == HOST


def test_arena_reservation_and_free():
    ctx = make_ctx()
    arena = ctx.spaces[ACC].arena
    hd = ctx.malloc((1024,), np.uint8, spaces=[ACC])
    assert arena.used_bytes == 1024
    ctx.free(hd)
    assert arena.used_bytes == 0


def test_flag_check_and_single_copy():
    ctx = make_ctx()
    hd = ctx.malloc((8,), np.float32)
    hd.data[:] = 3.0
    v1 = ctx.ensure(hd, ACC)  # one copy
    assert ctx.ledger.total_copies == 1
    ctx.mark_written(hd, ACC, v1 * 2)
    assert hd.last_location == ACC
    out = hete_sync(hd, context=ctx)  # one copy back
    np.testing.assert_allclose(out, 6.0)
    assert ctx.ledger.total_copies == 2


def test_faithful_flag_recopies_on_read_after_other_reader():
    """Paper semantics: a single last-resource flag → re-reading at a
    location that is not the flagged one re-copies (see DESIGN.md)."""
    ctx = make_ctx(tracking="flag")
    hd = ctx.malloc((8,), np.float32)
    ctx.ensure(hd, ACC)
    ctx.ensure(hd, ACC)  # flag still HOST → copies again
    assert ctx.ledger.total_copies == 2
    # cached (beyond-paper) mode keeps read replicas
    ctx2 = make_ctx(tracking="cached")
    hd2 = ctx2.malloc((8,), np.float32)
    ctx2.ensure(hd2, ACC)
    ctx2.ensure(hd2, ACC)
    assert ctx2.ledger.total_copies == 1


def test_write_invalidates_replicas():
    ctx = make_ctx(tracking="cached")
    hd = ctx.malloc((4,), np.float32)
    v = ctx.ensure(hd, ACC)
    ctx.mark_written(hd, ACC, v + 1)
    assert hd.valid_at == {ACC}


def test_fragment_indexing_and_views():
    ctx = make_ctx()
    hd = ctx.malloc((8 * 4,), np.float32)
    frags = hd.fragment(4)
    assert len(hd) == 8 and len(frags) == 8
    hd[3].data[:] = 7.0
    assert hd.data[12:16].tolist() == [7.0] * 4  # zero-copy view
    with pytest.raises(ValueError):
        hd[0].fragment(2)  # no nested fragmentation


def test_fragment_own_flags():
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.float32)
    hd.fragment(8)
    v = ctx.ensure(hd[0], ACC)
    ctx.mark_written(hd[0], ACC, v)
    assert hd[0].last_location == ACC
    assert hd[1].last_location == HOST  # sibling unaffected


def test_fragment_requires_divisor():
    ctx = make_ctx()
    hd = ctx.malloc((10,), np.float32)
    with pytest.raises(ValueError):
        hd.fragment(3)


def test_use_after_free_raises():
    ctx = make_ctx()
    hd = ctx.malloc((4,), np.float32)
    ctx.free(hd)
    with pytest.raises(AllocError):
        ctx.ensure(hd, ACC)
    with pytest.raises(AllocError):
        ctx.free(hd)


def test_ensure_reserves_arena_extent_on_materialization():
    """Satellite (ISSUE 1): device copies materialized by ensure() must
    reserve an extent, so MemorySpace.capacity is enforced at dispatch."""
    ctx = make_ctx()
    arena = ctx.spaces[ACC].arena
    hd = ctx.malloc((1024,), np.uint8)  # no spaces= → nothing reserved yet
    assert arena.used_bytes == 0
    ctx.ensure(hd, ACC)
    assert arena.used_bytes == 1024
    ctx.ensure(hd, ACC)  # re-copy (flag mode) must NOT double-reserve
    assert arena.used_bytes == 1024
    ctx.free(hd)
    assert arena.used_bytes == 0


def test_mark_written_reserves_arena_extent():
    ctx = make_ctx()
    arena = ctx.spaces[ACC].arena
    hd = ctx.malloc((512,), np.uint8)
    ctx.mark_written(hd, ACC, np.ones((512,), np.uint8))
    assert arena.used_bytes == 512
    ctx.free(hd)
    assert arena.used_bytes == 0


def test_ensure_raises_clear_allocerror_on_exhaustion():
    """ISSUE 2: exhaustion now evicts transparently; AllocError surfaces
    only when the pinned working set genuinely exceeds capacity."""
    ctx = HeteContext()
    ctx.register_space(MemorySpace(
        ACC, capacity=4096, allocator="nextfit",
        ingest=lambda a: a.copy(), egress=lambda a: np.asarray(a),
    ))
    big = ctx.malloc((3000,), np.uint8)
    ctx.ensure(big, ACC)
    too_big = ctx.malloc((3000,), np.uint8)
    with big.pinned(ACC):  # pinned resident → nothing evictable
        with pytest.raises(AllocError, match="exhausted"):
            ctx.ensure(too_big, ACC)
    # unpinned: the runtime spills `big` back to host and retries
    ctx.ensure(too_big, ACC)
    assert ctx.ledger.total_evictions == 1
    assert ACC not in big.copies and big.last_location.kind == "host"


def test_fragment_reservation_charges_parent_once():
    """§3.2.3: materializing fragments charges ONE parent-sized extent —
    one arena search covers all n fragments."""
    ctx = make_ctx()
    arena = ctx.spaces[ACC].arena
    hd = ctx.malloc((64,), np.float32)
    hd.fragment(16)
    for i in range(4):
        ctx.ensure(hd[i], ACC)
    assert arena.n_allocs == 1
    assert arena.used_bytes == hd.nbytes
    ctx.free(hd)
    assert arena.used_bytes == 0


def test_fragment_of_device_parent():
    """Satellite (ISSUE 1): fragments of a parent whose valid copy lives
    on a device must not expose the stale host view — ensure/sync on the
    fragment resolves to the device bytes (pinned semantics)."""
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.float32)
    hd.data[:] = 1.0
    dev = ctx.ensure(hd, ACC)
    ctx.mark_written(hd, ACC, dev * 3.0)  # device now holds the valid bytes
    assert hd.last_location == ACC
    frags = hd.fragment(8)
    for f in frags:
        assert f.last_location == ACC  # inherits the parent's flag
        np.testing.assert_allclose(hete_sync(f, context=ctx), 3.0)
    # sync wrote through the zero-copy view: parent host buffer is current
    np.testing.assert_allclose(hd.data, 3.0)


def test_parent_write_after_fragment_propagates_to_fragments():
    """Coherence: a whole-parent write supersedes fragment copies — a
    fragment read afterwards sees the new bytes, on device and host."""
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.float32)
    hd.data[:] = 1.0
    dev = ctx.ensure(hd, ACC)
    ctx.mark_written(hd, ACC, dev * 2.0)
    hd.fragment(8)
    # rewrite the WHOLE parent on device after fragmentation
    ctx.mark_written(hd, ACC, ctx.ensure(hd, ACC) * 2.0)  # now 4.0
    for f in hd.fragments:
        assert f.last_location == ACC
        np.testing.assert_allclose(hete_sync(f, context=ctx), 4.0)
    # host-side whole-parent write must keep the zero-copy views intact
    ctx.mark_written(hd, HOST, np.full((16,), 7.0, np.float32))
    assert hd[0].last_location == HOST
    np.testing.assert_allclose(hd[0].data, 7.0)


def test_fragment_write_then_whole_parent_read_gathers():
    """Coherence: fragment device writes are visible to a later whole-
    parent read (ensure/sync gathers the fragments' bytes first)."""
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.float32)
    hd.data[:] = 1.0
    frags = hd.fragment(8)
    v = ctx.ensure(frags[0], ACC)
    ctx.mark_written(frags[0], ACC, v * 5.0)  # fragment 0 → 5.0 on device
    out = hete_sync(hd, context=ctx)  # whole-parent read
    np.testing.assert_allclose(out[:8], 5.0)
    np.testing.assert_allclose(out[8:], 1.0)
    assert hd.last_location == HOST


def test_parent_host_sync_keeps_fragment_views_aliased():
    """Coherence: a whole-parent device→host sync must copy into the
    existing host buffer (not rebind it), so fragment views stay aliased
    and later host-side parent writes remain visible to fragments."""
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.float32)
    hd.data[:] = 1.0
    hd.fragment(8)
    dev = ctx.ensure(hd, ACC)
    ctx.mark_written(hd, ACC, dev * 2.0)
    np.testing.assert_allclose(hete_sync(hd, context=ctx), 2.0)  # parent sync
    ctx.mark_written(hd, HOST, np.full((16,), 7.0, np.float32))
    np.testing.assert_allclose(hd[0].data, 7.0)  # view still aliases
    np.testing.assert_allclose(hete_sync(hd[0], context=ctx), 7.0)


def test_free_parent_frees_fragments():
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.float32)
    frags = hd.fragment(8)
    with pytest.raises(ValueError):
        ctx.free(frags[0])
    ctx.free(hd)
    assert frags[0].freed
