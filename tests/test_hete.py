"""hete_Data / hete_Malloc / hete_Free / hete_Sync semantics (§3.2)."""

import numpy as np
import pytest

from repro.core.allocator import AllocError
from repro.core.hete import HeteContext, MemorySpace, hete_sync
from repro.core.locations import HOST, Location

ACC = Location("device", "acc0")


def make_ctx(tracking="flag"):
    ctx = HeteContext(tracking=tracking)
    ctx.register_space(MemorySpace(
        ACC, capacity=1 << 20, allocator="nextfit",
        ingest=lambda a: a.copy(), egress=lambda a: np.asarray(a),
    ))
    return ctx


def test_malloc_gives_host_buffer():
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.float32)
    assert hd.data.shape == (16,)
    assert hd.last_location == HOST


def test_arena_reservation_and_free():
    ctx = make_ctx()
    arena = ctx.spaces[ACC].arena
    hd = ctx.malloc((1024,), np.uint8, spaces=[ACC])
    assert arena.used_bytes == 1024
    ctx.free(hd)
    assert arena.used_bytes == 0


def test_flag_check_and_single_copy():
    ctx = make_ctx()
    hd = ctx.malloc((8,), np.float32)
    hd.data[:] = 3.0
    v1 = ctx.ensure(hd, ACC)  # one copy
    assert ctx.ledger.total_copies == 1
    ctx.mark_written(hd, ACC, v1 * 2)
    assert hd.last_location == ACC
    out = hete_sync(hd, context=ctx)  # one copy back
    np.testing.assert_allclose(out, 6.0)
    assert ctx.ledger.total_copies == 2


def test_faithful_flag_recopies_on_read_after_other_reader():
    """Paper semantics: a single last-resource flag → re-reading at a
    location that is not the flagged one re-copies (see DESIGN.md)."""
    ctx = make_ctx(tracking="flag")
    hd = ctx.malloc((8,), np.float32)
    ctx.ensure(hd, ACC)
    ctx.ensure(hd, ACC)  # flag still HOST → copies again
    assert ctx.ledger.total_copies == 2
    # cached (beyond-paper) mode keeps read replicas
    ctx2 = make_ctx(tracking="cached")
    hd2 = ctx2.malloc((8,), np.float32)
    ctx2.ensure(hd2, ACC)
    ctx2.ensure(hd2, ACC)
    assert ctx2.ledger.total_copies == 1


def test_write_invalidates_replicas():
    ctx = make_ctx(tracking="cached")
    hd = ctx.malloc((4,), np.float32)
    v = ctx.ensure(hd, ACC)
    ctx.mark_written(hd, ACC, v + 1)
    assert hd.valid_at == {ACC}


def test_fragment_indexing_and_views():
    ctx = make_ctx()
    hd = ctx.malloc((8 * 4,), np.float32)
    frags = hd.fragment(4)
    assert len(hd) == 8 and len(frags) == 8
    hd[3].data[:] = 7.0
    assert hd.data[12:16].tolist() == [7.0] * 4  # zero-copy view
    with pytest.raises(ValueError):
        hd[0].fragment(2)  # no nested fragmentation


def test_fragment_own_flags():
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.float32)
    hd.fragment(8)
    v = ctx.ensure(hd[0], ACC)
    ctx.mark_written(hd[0], ACC, v)
    assert hd[0].last_location == ACC
    assert hd[1].last_location == HOST  # sibling unaffected


def test_fragment_requires_divisor():
    ctx = make_ctx()
    hd = ctx.malloc((10,), np.float32)
    with pytest.raises(ValueError):
        hd.fragment(3)


def test_use_after_free_raises():
    ctx = make_ctx()
    hd = ctx.malloc((4,), np.float32)
    ctx.free(hd)
    with pytest.raises(AllocError):
        ctx.ensure(hd, ACC)
    with pytest.raises(AllocError):
        ctx.free(hd)


def test_free_parent_frees_fragments():
    ctx = make_ctx()
    hd = ctx.malloc((16,), np.float32)
    frags = hd.fragment(8)
    with pytest.raises(ValueError):
        ctx.free(frags[0])
    ctx.free(hd)
    assert frags[0].freed
