"""Continuous telemetry (ISSUE 8): divergence monitor, histogram merge,
sampler lifecycle, Prometheus exposition, SLO burn rates, cross-process
metric aggregation, the worker-span lint check, and the profile CLI."""

import json
import math
import threading
import urllib.request
import warnings

import numpy as np
import pytest

from repro.apps.radar import make_session, submit_2fzf
from repro.core import telemetry
from repro.core.telemetry import (
    DivergenceMonitor,
    Sampler,
    metrics_text,
    serve_metrics,
    shape_bucket,
    slo_eval,
)
from repro.core.trace import Histogram, MetricsRegistry, trace_lint


# ---------------------------------------------------------------------------
# Histogram.merge (satellite)
# ---------------------------------------------------------------------------


def test_histogram_merge_empty():
    a, b = Histogram("a"), Histogram("b")
    a.merge(b)
    assert a.count == 0 and a.percentile(50) is None
    b.record(3.0)
    a.merge(b)
    assert a.count == 1 and a.percentile(50) == 3.0
    # merging an empty into a populated one changes nothing
    before = a.to_state()
    a.merge(Histogram("c"))
    assert a.to_state() == before


def test_histogram_merge_single_sample():
    a, b = Histogram(), Histogram()
    a.record(1.0)
    b.record(100.0)
    a.merge(b)
    assert a.count == 2
    assert a.min == 1.0 and a.max == 100.0
    assert a.sum == 101.0
    assert a.percentile(50) is not None


def test_histogram_merge_associative_across_bucket_boundaries():
    rng = np.random.default_rng(11)
    xs = rng.lognormal(mean=-4.0, sigma=2.0, size=900)  # spans many octaves
    parts = [Histogram(), Histogram(), Histogram()]
    for i, x in enumerate(xs):
        parts[i % 3].record(float(x))

    def state(h):
        s = h.to_state()
        s.pop("name")
        # float summation order differs between merge orders; compare
        # the running sum to tolerance, everything else exactly
        assert abs(s.pop("sum") - xs.sum()) < 1e-9
        return s

    # (a + b) + c == a + (b + c) == single histogram of all samples
    ab_c = Histogram().merge(parts[0]).merge(parts[1]).merge(parts[2])
    bc = Histogram().merge(parts[1]).merge(parts[2])
    a_bc = Histogram().merge(parts[0]).merge(bc)
    direct = Histogram()
    for x in xs:
        direct.record(float(x))
    assert state(ab_c) == state(a_bc) == state(direct)
    for q in (50, 95, 99):
        assert ab_c.percentile(q) == direct.percentile(q)


def test_histogram_state_roundtrip_through_json():
    h = Histogram("lat")
    for v in (0.0, 1e-6, 0.5, 3.0, 4096.0):
        h.record(v)
    state = json.loads(json.dumps(h.to_state()))
    back = Histogram.from_state(state)
    assert back.count == h.count and back.sum == h.sum
    assert back.percentile(95) == h.percentile(95)


# ---------------------------------------------------------------------------
# MetricsRegistry state/merge (cross-process aggregation)
# ---------------------------------------------------------------------------


def test_registry_state_merge_counters_and_histograms():
    a = MetricsRegistry()
    a.counter("worker/gpu0/tasks").inc(3)
    a.histogram("worker/gpu0/kernel_s").record(0.5)
    a.gauge("g").set(7.0)  # gauges deliberately excluded from state()
    state = json.loads(json.dumps(a.state()))
    assert "g" not in state["counters"] and "g" not in state["histograms"]

    parent = MetricsRegistry()
    parent.counter("worker/gpu0/tasks").inc(2)
    parent.merge_state(state)
    parent.merge_state({"counters": {}, "histograms": {}})  # empty is fine
    assert parent.counter("worker/gpu0/tasks").value == 5
    assert parent.histogram("worker/gpu0/kernel_s").count == 1


# ---------------------------------------------------------------------------
# DivergenceMonitor
# ---------------------------------------------------------------------------


def test_shape_bucket_labels():
    assert shape_bucket(0) == "0B"
    assert shape_bucket(1) == "<=1B"
    assert shape_bucket(65_536) == "<=64KiB"
    assert shape_bucket(65_537) == "<=128KiB"


def test_divergence_observe_table_and_skips():
    mon = DivergenceMonitor(register=False)
    for _ in range(10):
        mon.observe("compute", "fft", "gpu", 1 << 16, 2e-3, 1e-3)
    mon.observe("compute", "fft", "gpu", 1 << 16, 0.0, 1e-3)  # skipped
    mon.observe("compute", "fft", "gpu", 1 << 16, 1e-3, 0.0)  # skipped
    table = mon.table()
    cell = table["compute/fft/gpu/<=64KiB"]
    assert cell["count"] == 10 and cell["skipped"] == 2
    assert abs(cell["ema_ratio"] - 2.0) < 1e-9
    assert abs(cell["mean_ratio"] - 2.0) < 1e-9
    assert cell["p50_ratio"] is not None and cell["p50_ratio"] > 0


def test_divergence_merge_and_json_roundtrip(tmp_path):
    a = DivergenceMonitor(register=False)
    b = DivergenceMonitor(register=False)
    for _ in range(4):
        a.observe("compute", "fft", "gpu", 1024, 1.5e-3, 1e-3)
        b.observe("compute", "fft", "gpu", 1024, 3e-3, 1e-3)
        b.observe("stage", "zip", "cpu", 2048, 1e-4, 2e-4)
    merged = DivergenceMonitor(register=False)
    merged.merge(a.state())
    merged.merge(b.state())
    t = merged.table()
    assert t["compute/fft/gpu/<=1KiB"]["count"] == 8
    assert t["stage/zip/cpu/<=2KiB"]["count"] == 4
    # count-weighted EMA blend lands between the two monitors' EMAs
    assert 1.5 < t["compute/fft/gpu/<=1KiB"]["ema_ratio"] < 3.0

    # The raw-JSON path is deprecated (ISSUE 10) in favor of calibration
    # tables: exactly one DeprecationWarning per process, then silence.
    path = tmp_path / "divergence.json"
    telemetry._divergence_json_warned = False
    with pytest.warns(DeprecationWarning, match="calibration"):
        merged.save_json(str(path))
    doc = json.loads(path.read_text())
    assert doc["format"] == "rimms-divergence-v1"
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second call must stay quiet
        back = DivergenceMonitor.load_json(str(path))
    assert back.table() == t


def test_divergence_serial_scopes_aggregation():
    mark = telemetry.divergence_serial()
    mon = DivergenceMonitor()  # registered
    mon.observe("compute", "op", "cpu", 64, 1e-3, 1e-3)
    agg = telemetry.aggregate_divergence(since=mark)
    assert "compute/op/cpu/<=64B" in agg.table()
    # a later mark excludes it
    assert telemetry.aggregate_divergence(
        since=telemetry.divergence_serial()).table() == {}


def test_runtime_populates_divergence_for_compute_and_stage():
    session = make_session(n_cpu=1, accelerators=("gpu0",))
    try:
        out = submit_2fzf(session, 256, seed=3)["out"]
        out.result(timeout=120)
    finally:
        session.close()
        rt = session.runtime
        table = rt.divergence.table()
        rt.close()
    kinds = {c["kind"] for c in table.values()}
    assert "compute" in kinds and "stage" in kinds
    compute = [c for c in table.values()
               if c["kind"] == "compute" and c["count"] > 0]
    assert compute, table
    assert all(math.isfinite(c["ema_ratio"]) and c["ema_ratio"] > 0
               for c in compute)
    # qos_report surfaces the same table
    # (report built before close in normal use; table is identical)


def test_qos_report_has_divergence_section():
    session = make_session(n_cpu=1, accelerators=("gpu0",))
    try:
        submit_2fzf(session, 128, seed=1)["out"].result(timeout=120)
        session.barrier()
        rep = session.qos_report()
        assert isinstance(rep["divergence"], dict)
        assert rep["slo"] == {}  # no objectives declared
    finally:
        session.close()
        session.runtime.close()


# ---------------------------------------------------------------------------
# Sampler lifecycle (satellite)
# ---------------------------------------------------------------------------


def _make_session(**kw):
    return make_session(n_cpu=1, accelerators=("gpu0",), **kw)


def test_sampler_manual_tick_deterministic_and_bounded():
    session = _make_session()
    try:
        sampler = session.start_sampler(period=0.0, max_samples=8)
        assert not sampler.running  # manual mode: no thread
        for _ in range(20):
            s = sampler.tick()
            assert s is not None
        assert sampler.ticks == 20
        assert len(sampler.samples) == 8  # bounded ring
        seqs = [s["seq"] for s in sampler.samples]
        assert seqs == list(range(13, 21))  # oldest evicted, in order
        sample = sampler.samples[-1]
        gauges = sample["gauges"]
        assert any(k.startswith("pe_queue_depth/") for k in gauges)
        assert any(k.startswith("pe_busy/") for k in gauges)
        assert any(k.startswith("arena_free_bytes/") for k in gauges)
        assert any(k.startswith("arena_used_bytes/") for k in gauges)
        assert any(k.startswith("arena_pinned_bytes/") for k in gauges)
        assert "pressure_evictions" in gauges
        assert any(k.startswith("tenant_window_occupancy/")
                   for k in gauges) or not session.qos.snapshot()["clients"]
        # gauges mirrored into the session registry
        snap = session.metrics.snapshot()
        for name in gauges:
            assert snap[name]["value"] == gauges[name]
    finally:
        session.close()
        session.runtime.close()


def test_sampler_stops_with_session_close():
    session = _make_session(sampler_period=0.005)
    sampler = session.sampler
    assert sampler is not None and sampler.running
    submit_2fzf(session, 128, seed=2)["out"].result(timeout=120)
    session.close()
    assert not sampler.running
    n = sampler.ticks
    assert sampler.tick() is None  # no samples after close
    assert sampler.ticks == n
    session.runtime.close()


def test_sampler_background_thread_ticks_and_ring_is_bounded():
    session = _make_session()
    try:
        sampler = session.start_sampler(period=0.001, max_samples=16)
        assert sampler.running
        deadline = threading.Event()
        for _ in range(200):
            if sampler.ticks >= 20:
                break
            deadline.wait(0.01)
        assert sampler.ticks >= 20
        assert len(sampler.samples) <= 16
    finally:
        session.close()
        session.runtime.close()
    assert not sampler.running


def test_sampler_rejects_bad_params():
    session = _make_session()
    try:
        for kw in ({"period": -1.0}, {"max_samples": 0}):
            try:
                Sampler(session, **kw)
                raise AssertionError(f"expected ValueError for {kw}")
            except ValueError:
                pass
    finally:
        session.close()
        session.runtime.close()


# ---------------------------------------------------------------------------
# Prometheus exposition + HTTP endpoint
# ---------------------------------------------------------------------------


def test_metrics_text_format():
    reg = MetricsRegistry()
    reg.counter("copies/host->gpu0").inc(4)
    reg.gauge("arena_free_bytes/gpu0").set(1024.0)
    reg.histogram("latency_model_s/clientA").record(0.5)
    reg.histogram("latency_model_s/clientA").record(2.0)
    text = metrics_text(reg)
    assert text.endswith("\n")
    lines = text.splitlines()
    assert "# TYPE rimms_copies_total counter" in lines
    assert 'rimms_copies_total{key="host->gpu0"} 4' in lines
    assert "# TYPE rimms_arena_free_bytes gauge" in lines
    assert 'rimms_arena_free_bytes{key="gpu0"} 1024.0' in lines
    assert "# TYPE rimms_latency_model_s summary" in lines
    assert any(l.startswith('rimms_latency_model_s{key="clientA",'
                            'quantile="0.5"}') for l in lines)
    assert 'rimms_latency_model_s_sum{key="clientA"} 2.5' in lines
    assert 'rimms_latency_model_s_count{key="clientA"} 2' in lines
    # deterministic
    assert text == metrics_text(reg)
    # empty-histogram summaries render without quantile lines
    reg2 = MetricsRegistry()
    reg2.histogram("h")
    t2 = metrics_text(reg2)
    assert "quantile" not in t2 and "rimms_h_count 0" in t2


def test_serve_metrics_http_endpoint():
    reg = MetricsRegistry()
    reg.counter("hits").inc(2)
    server = serve_metrics(reg)
    try:
        with urllib.request.urlopen(server.url, timeout=10) as resp:
            assert resp.status == 200
            assert "version=0.0.4" in resp.headers["Content-Type"]
            body = resp.read().decode()
        assert "rimms_hits_total 2" in body
        bad = server.url.replace("/metrics", "/nope")
        try:
            urllib.request.urlopen(bad, timeout=10)
            raise AssertionError("expected 404")
        except urllib.error.HTTPError as e:
            assert e.code == 404
    finally:
        server.close()


def test_session_metrics_text_and_server():
    session = _make_session()
    try:
        session.metrics.counter("submitted").inc()
        text = session.metrics_text()
        assert "rimms_submitted_total 1" in text
        server = session.serve_metrics()
        try:
            with urllib.request.urlopen(server.url, timeout=10) as resp:
                assert "rimms_submitted_total 1" in resp.read().decode()
        finally:
            server.close()
    finally:
        session.close()
        session.runtime.close()


# ---------------------------------------------------------------------------
# SLO burn rates
# ---------------------------------------------------------------------------


def test_slo_eval_math():
    s = slo_eval([0.1] * 98 + [3.0] * 2, 1.0, 0.99)
    assert s["tasks"] == 100 and s["violations"] == 2
    assert abs(s["violation_rate"] - 0.02) < 1e-12
    assert abs(s["burn_rate"] - 2.0) < 1e-9 and s["breached"]
    s2 = slo_eval([0.1], 1.0, 0.99)
    assert s2["violations"] == 0 and not s2["breached"]
    assert slo_eval([], 1.0, 0.99)["burn_rate"] == 0.0
    for bad in ((0.0, 0.99), (1.0, 0.0), (1.0, 1.0)):
        try:
            slo_eval([1.0], *bad)
            raise AssertionError(f"expected ValueError for {bad}")
        except ValueError:
            pass


def test_qos_report_slo_section_and_trace_instants():
    session = _make_session(trace=True)
    try:
        # objective below the 20us modeled launch floor -> every task of
        # this client violates; the loose client never does
        tight = session.client("tight", slo_latency_s=1e-6)
        loose = session.client("loose", slo_latency_s=60.0,
                               slo_target=0.9)
        submit_2fzf(session, 128, seed=5, tag="_t")["out"].result(
            timeout=120)
        f = session.submit("fft", [session.malloc((128,), np.complex64)],
                           client=tight, name="tightfft")
        g = session.submit("fft", [session.malloc((128,), np.complex64)],
                           client=loose, name="loosefft")
        f.result(timeout=120)
        g.result(timeout=120)
        session.barrier()
        rep = session.qos_report()
        slo = rep["slo"]
        assert slo["tight"]["violations"] == slo["tight"]["tasks"] == 1
        assert slo["tight"]["breached"]
        assert slo["loose"]["violations"] == 0
        assert not slo["loose"]["breached"]
        assert slo["loose"]["target"] == 0.9
        assert set(slo) == {"tight", "loose"}
        session.close()
        doc = session.export_trace()
        instants = [e for e in doc["traceEvents"]
                    if e.get("cat") == "slo"]
        assert len(instants) == 1
        ev = instants[0]
        assert ev["name"] == "slo_violation"
        assert ev["args"]["task"] == "tightfft"
        assert ev["args"]["latency_s"] > ev["args"]["objective_s"]
        # divergence table rides in the exported doc too
        assert "divergence" in doc["rimms"]
    finally:
        session.close()
        session.runtime.close()


def test_client_slo_validation():
    session = _make_session()
    try:
        for kw in ({"slo_latency_s": 0.0}, {"slo_latency_s": 1.0,
                                            "slo_target": 1.5}):
            try:
                session.client("bad", **kw)
                raise AssertionError(f"expected ValueError for {kw}")
            except ValueError:
                pass
    finally:
        session.close()
        session.runtime.close()


# ---------------------------------------------------------------------------
# trace_lint worker-span check (satellite)
# ---------------------------------------------------------------------------


def _worker_doc(*, backend="process", nested=True):
    """A minimal two-track doc: parent compute span + forwarded worker
    span (nested and tagged, unless told otherwise)."""
    w0, w1 = (100.0, 900.0) if nested else (2000.0, 3000.0)
    args = {"backend": backend} if backend else {}
    return {
        "traceEvents": [
            {"ph": "M", "name": "thread_name", "pid": 1, "tid": 1,
             "args": {"name": "pe:gpu0"}},
            {"ph": "M", "name": "thread_name", "pid": 1, "tid": 2,
             "args": {"name": "pe:gpu0:worker"}},
            {"ph": "X", "name": "t", "cat": "compute", "pid": 1, "tid": 1,
             "ts": 0.0, "dur": 1000.0},
            {"ph": "X", "name": "t", "cat": "compute", "pid": 1, "tid": 2,
             "ts": w0, "dur": w1 - w0, "args": args},
        ],
        "rimms": {"drops": 0, "ledgers": {}},
    }


def test_trace_lint_accepts_nested_tagged_worker_span():
    assert trace_lint(_worker_doc()) == []


def test_trace_lint_flags_untagged_worker_span():
    violations = trace_lint(_worker_doc(backend=None))
    assert any("backend" in v for v in violations)


def test_trace_lint_flags_orphaned_worker_span():
    violations = trace_lint(_worker_doc(nested=False))
    assert any("orphaned worker span" in v for v in violations)


# ---------------------------------------------------------------------------
# profile CLI
# ---------------------------------------------------------------------------


def test_profile_report_over_real_trace(tmp_path, capsys):
    from repro import profile as profile_cli

    session = _make_session(trace=True)
    try:
        submit_2fzf(session, 256, seed=9)["out"].result(timeout=120)
        session.barrier()
        session.close()
        session.context.tracer.set_divergence(
            session.runtime.divergence.table())
        path = tmp_path / "TRACE_t.json"
        session.export_trace(str(path))
    finally:
        session.close()
        session.runtime.close()

    rc = profile_cli.main([str(path)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "Top ops by wall time" in out
    assert "Top ops by modeled time" in out
    assert "| fft |" in out
    assert "Critical path" in out
    # the 2FZF chain has dependencies -> a multi-task critical path
    assert "tasks," in out
    assert "Wall/modeled divergence" in out
    assert "| compute | " in out


def test_profile_cli_fails_on_malformed_input(tmp_path, capsys):
    from repro import profile as profile_cli

    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    missing = tmp_path / "missing.json"
    assert profile_cli.main([str(bad)]) == 1
    assert profile_cli.main([str(missing)]) == 1
    err = capsys.readouterr().err
    assert "traceEvents" in err


# ---------------------------------------------------------------------------
# cross-process worker metrics
# ---------------------------------------------------------------------------


def test_process_workers_merge_metrics_into_session_registry():
    session = make_session(n_cpu=0, accelerators=("gpu0",),
                           backend="process")
    try:
        submit_2fzf(session, 256, seed=4, pins=("gpu0",) * 4)[
            "out"].result(timeout=600)
        session.barrier()
        session.close()
        snap = session.metrics.snapshot()
        assert snap["worker/gpu0/tasks"]["value"] == 4  # fft,fft,zip,ifft
        ks = snap["worker/gpu0/kernel_s"]
        assert ks["count"] == 4 and ks["sum"] > 0
        # drain semantics: a second collect adds nothing
        pool = session.runtime._process_pool
        before = session.metrics.counter("worker/gpu0/tasks").value
        assert pool.collect_metrics(session.metrics) >= 1
        assert session.metrics.counter(
            "worker/gpu0/tasks").value == before
    finally:
        session.close()
        session.runtime.close()
